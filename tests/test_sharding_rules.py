"""Unit tests for the logical-axis -> mesh-axis resolver (pure; no
devices needed — Mesh is built abstractly)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import abstract_mesh
from repro.distributed.sharding import make_rules, spec_for

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
RULES = make_rules(False, fsdp=True)
RULES3 = make_rules(True, fsdp=True)


def test_tp_and_fsdp_assignment():
    # (embed, mlp) weight: embed->data (FSDP), mlp->model (TP)
    assert spec_for(("embed", "mlp"), RULES, MESH, (4096, 14336)) == \
        P("data", "model")


def test_axis_used_once_per_array():
    # (experts, embed, mlp): experts takes model first; mlp must not reuse
    spec = spec_for(("experts", "embed", "mlp"), RULES, MESH,
                    (160, 5120, 1536))
    assert spec == P("model", "data")           # trailing None trimmed


def test_divisibility_fallback():
    # 8 kv heads cannot shard 16 ways -> replicated
    assert spec_for(("kv_heads", "head_dim"), RULES, MESH, (8, 128)) == P()
    # vocab not divisible by 16 -> falls through model AND data -> None
    assert spec_for(("vocab", "embed"), RULES, MESH, (50280, 2048)) == \
        P(None, "data")


def test_seq_kv_takes_both_axes_when_batch_absent():
    # long_500k: batch=1 unshardable => seq gets data AND model (256-way)
    spec = spec_for(("batch", "seq_kv", "kv_heads", "head_dim"), RULES,
                    MESH, (1, 524288, 8, 128))
    assert spec == P(None, ("data", "model"))


def test_seq_kv_model_only_when_batch_holds_data():
    spec = spec_for(("batch", "seq_kv", "kv_heads", "head_dim"), RULES,
                    MESH, (128, 32768, 8, 128))
    assert spec == P("data", "model")


def test_multipod_batch_spans_pod_and_data():
    spec = spec_for(("batch", None, None), RULES3, MESH3, (256, 4096, 1))
    assert spec == P(("pod", "data"))


def test_kv_lora_never_takes_model():
    # contraction dim: model-sharding it costs a psum per flash block
    spec = spec_for(("kv_lora", "q_heads", "head_dim"), RULES, MESH,
                    (512, 128, 128))
    assert spec == P("data", "model")
