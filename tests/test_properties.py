"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import SketchConfig, StreamingHistogram, instrument
from repro.core.specialize import SiteSpec, SpecializationPlan
from repro.kernels import ref as R
from repro.launch import hlo_analysis as H
from repro.models.model import cross_entropy
from repro.testing import plan_fingerprint

SK = SketchConfig(width=256, candidates=64)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=200))
def test_count_min_never_undercounts(keys):
    """CMS point estimates are always >= true counts."""
    state = instrument.init_site_state(SK)
    state = instrument.record(state, jnp.asarray(keys, jnp.int32), SK)
    uniq, counts = np.unique(keys, return_counts=True)
    est = np.asarray(instrument.estimate(state, jnp.asarray(uniq)))
    assert (est >= counts).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(1, 8))
def test_sketch_total_tracks_records(n_keys, n_rounds):
    state = instrument.init_site_state(SK)
    for _ in range(n_rounds):
        state = instrument.record(
            state, jnp.arange(n_keys, dtype=jnp.int32), SK)
    assert int(state["total"]) == n_keys * n_rounds


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(8, 64), st.integers(1, 4))
def test_attention_rows_sum_to_one(b, s, h):
    """Softmax invariance: output is a convex combination of V rows, so
    attention of constant-v inputs returns that constant."""
    key = jax.random.PRNGKey(b * 1000 + s)
    q = jax.random.normal(key, (b, s, h, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, 16))
    v = jnp.ones((b, s, h, 16))
    out = R.flash_attention_ref(q, k, v, causal=True, block=16)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(4, 32))
def test_ssd_zero_input_zero_output(b, s):
    """SSD is linear in x: zero input => zero output and zero state."""
    key = jax.random.PRNGKey(s)
    H_, P, N = 2, 4, 8
    x = jnp.zeros((b, s, H_, P))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, H_)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (H_,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, 1, N))
    y, fin = R.ssd_scan_ref(x, dt, A, Bm, Cm, 8)
    assert float(jnp.abs(y).max()) == 0.0
    assert float(jnp.abs(fin).max()) == 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 50), st.integers(51, 80))
def test_vocab_padding_does_not_change_loss(vocab, padded):
    """Masked-CE invariant: padded logit columns never affect the loss."""
    key = jax.random.PRNGKey(vocab)
    logits = jax.random.normal(key, (2, 8, padded))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (2, 8), 0,
                                vocab)
    base = cross_entropy(logits[..., :vocab], labels)
    padded_loss = cross_entropy(
        logits.at[..., vocab:].set(1e4), labels, n_valid=vocab)
    np.testing.assert_allclose(float(base), float(padded_loss), rtol=1e-5)


_site_specs = st.builds(
    SiteSpec,
    impl=st.sampled_from(["gather", "onehot", "hot_cache",
                          "moe_fastpath", "ssd_fastpath"]),
    hot_keys=st.lists(st.integers(0, 255), max_size=4).map(tuple),
    guarded=st.booleans())
_sites = st.lists(
    st.tuples(st.sampled_from(["a#0", "a#1", "b#0", "c#0"]),
              _site_specs),
    max_size=4, unique_by=lambda s: s[0]).map(tuple)
_flags = st.dictionaries(st.sampled_from(["f1", "f2", "f3"]),
                         st.booleans(), max_size=3)


@settings(max_examples=30, deadline=None)
@given(_sites, _flags, st.booleans(), st.integers(0, 1000),
       st.integers(0, 1000))
def test_plan_signature_pure_in_sites_flags_instrumented(
        sites, flags, instrumented, v1, v2):
    """The signature (and its canonical fingerprint) is a pure function
    of (sites, flags, instrumented): version and label never leak in —
    that is what lets one compiled executable serve behaviorally
    identical plans across control-plane versions."""
    p1 = SpecializationPlan(version=v1, sites=sites, flags=dict(flags),
                            instrumented=instrumented, label="x")
    p2 = SpecializationPlan(version=v2, sites=sites, flags=dict(flags),
                            instrumented=instrumented, label="y")
    assert p1.signature == p2.signature
    assert plan_fingerprint(p1) == plan_fingerprint(p2)
    # ... and each component IS load-bearing
    p3 = SpecializationPlan(version=v1, sites=sites, flags=dict(flags),
                            instrumented=not instrumented)
    assert plan_fingerprint(p3) != plan_fingerprint(p1)
    flipped = dict(flags)
    flipped["f1"] = not flipped.get("f1", False)
    p4 = SpecializationPlan(version=v1, sites=sites, flags=flipped,
                            instrumented=instrumented)
    assert plan_fingerprint(p4) != plan_fingerprint(p1)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=1e-6, max_value=1e3,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=300),
       st.floats(min_value=0.0, max_value=1.0))
def test_histogram_quantile_error_bound(xs, q):
    """StreamingHistogram.quantile stays within the documented ~5%
    relative-error bound of the true order statistic for any stream
    inside [lo, hi) — including adversarial two-point extreme streams.

    The reference MUST be the order statistic (``method="inverted_cdf"``
    = sorted[ceil(q*n)-1]): numpy's default linear interpolation
    invents values between observations, which a two-point stream like
    [1e-6, 1e3] at q=0.5 places ~9 decades away from anything the
    histogram (correctly) returns."""
    h = StreamingHistogram()          # lo=1e-7, hi=1e4, 512 buckets
    h.observe_all(xs)
    got = h.quantile(q)
    want = float(np.quantile(np.asarray(xs), q, method="inverted_cdf"))
    assert got == pytest.approx(want, rel=0.06)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 16))
def test_hlo_while_multiplier(trips, width):
    """The analyzer multiplies while-body work by the trip count."""
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        c, _ = jax.lax.scan(body, x, w)
        return c
    x = jax.ShapeDtypeStruct((width * 8, width * 8), jnp.float32)
    w = jax.ShapeDtypeStruct((trips, width * 8, width * 8), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    ana = H.analyze(txt)
    expected = 2.0 * trips * (width * 8) ** 3
    assert abs(ana["flops"] - expected) / expected < 0.05
