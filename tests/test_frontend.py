"""The request-level serving frontend, end to end.

Covers the PR's acceptance criteria: the ragged->bucket packer's masked
rows never perturb real rows; the bounded queue rejects at capacity and
sheds expired deadlines; a mid-serve control-plane update deopts
without dropping or reordering queued requests; open-loop arrivals
through the frontend produce per-request outputs byte-identical to
one-per-batch execution; BatchShapePass selects pad buckets + window
depth K from the observed arrival profile (visible in ``plan.sites``)
and bucket misprediction deopts through the existing program guard;
``step_many`` serves non-example batch structures at every K;
``warm_fused`` precompiles all of a shape's role executables; and the
shared :class:`StreamingHistogram` backs both step- and request-level
quantiles through one ``RuntimeStats`` implementation.
"""
import math
import time

import jax
import numpy as np
import pytest

from repro.core import BATCH_SHAPE_SITE, EngineConfig, MorpheusRuntime, \
    RuntimeStats, SketchConfig, StreamingHistogram, plan_batch_shape
from repro.serving import ServeConfig, build_params, build_tables, \
    make_request_batch, make_request_rows, make_serve_step, \
    make_synthetic_batch
from repro.serving.frontend import FrontendConfig, OpenLoopDriver, \
    Request, RequestQueue, ServingFrontend, bursty_onoff_gaps, \
    poisson_gaps

TINY = ServeConfig(d_model=32, n_layers=1, n_heads=4, vocab=128,
                   n_experts=4, d_ff=32, n_classes=8, n_slots=32, seq=4)


def _mk_rt(cfg=TINY, seed=0, batch_size=8):
    key = jax.random.PRNGKey(seed)
    return MorpheusRuntime(
        make_serve_step(cfg), build_tables(cfg, key),
        build_params(cfg, key),
        make_synthetic_batch(cfg, key, batch_size),
        cfg=EngineConfig(
            sketch=SketchConfig(sample_every=2, max_hot=4,
                                hot_coverage=0.6),
            features={"vision_enabled": False, "track_sessions": True},
            moe_router_table="router"))


class FakeClock:
    """Virtual monotonic clock for deterministic queue/deadline tests."""

    def __init__(self, t=100.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += float(dt)


class StubProfile:
    """A fixed profile snapshot — drives BatchShapePass deterministically."""

    def __init__(self, d):
        self.d = dict(d)

    def snapshot(self):
        return dict(self.d)


def _profile_dict(size_hist, rate, ladder=(1, 2, 4, 8), max_wait=2e-3,
                  k_max=4):
    return {"ladder": ladder, "max_wait_s": max_wait,
            "window_k_max": k_max, "arrival_rate_hz": rate,
            "size_hist": tuple(size_hist)}


# ---------------------------------------------------------------------------
# StreamingHistogram + RuntimeStats (one quantile implementation for
# step AND request latency)
# ---------------------------------------------------------------------------

def test_histogram_quantiles_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-6.0, sigma=1.5, size=20_000)
    h = StreamingHistogram()
    h.observe_all(xs)
    for q in (0.1, 0.5, 0.9, 0.99):
        exact = float(np.quantile(xs, q))
        # geometric buckets: ~5.1% relative bucket width
        assert h.quantile(q) == pytest.approx(exact, rel=0.06)
    assert h.quantile(0.0) == pytest.approx(xs.min(), rel=0.06)
    assert h.quantile(1.0) == pytest.approx(xs.max(), rel=0.06)
    assert h.mean == pytest.approx(xs.mean(), rel=1e-6)


def test_histogram_merge_equals_union():
    rng = np.random.default_rng(1)
    a, b = rng.exponential(0.01, 5000), rng.exponential(0.1, 5000)
    ha, hb, hu = (StreamingHistogram() for _ in range(3))
    ha.observe_all(a)
    hb.observe_all(b)
    hu.observe_all(np.concatenate([a, b]))
    ha.merge(hb)
    for q in (0.25, 0.5, 0.99):
        assert ha.quantile(q) == pytest.approx(hu.quantile(q), rel=1e-9)
    assert ha.summary()["count"] == 10_000


def test_histogram_empty():
    h = StreamingHistogram()
    assert math.isnan(h.quantile(0.5))
    assert h.summary() == {"count": 0}


def test_stats_observe_many_and_quantiles():
    s = RuntimeStats()
    s.observe_many({"request_total_s": [0.01, 0.02, 0.03],
                    "request_queue_wait_s": [0.001]},
                   requests_completed=3, slo_met=2, slo_missed=1)
    assert s.requests_completed == 3 and s.slo_met == 2
    assert s.quantile("request_total_s", 0.5) == pytest.approx(
        0.02, rel=0.06)
    assert math.isnan(s.quantile("no_such_series", 0.5))
    snap = s.snapshot()
    assert snap["hists"]["request_total_s"]["count"] == 3
    s.reset_hist("request_total_s")
    assert math.isnan(s.quantile("request_total_s", 0.5))
    # the untouched series survives a selective reset
    assert s.quantile("request_queue_wait_s", 0.5) > 0


# ---------------------------------------------------------------------------
# ragged -> bucket packer
# ---------------------------------------------------------------------------

def test_request_batch_shapes_and_mask():
    rows = make_request_rows(TINY, jax.random.PRNGKey(0), 3)
    b = make_request_batch(rows, 8)
    assert b["tokens"].shape == (8, TINY.seq)
    assert b["valid"].shape == (8,)
    np.testing.assert_array_equal(
        np.asarray(b["valid"]), [True] * 3 + [False] * 5)
    # pad rows replicate row 0 (deterministic duplicate-index scatters)
    np.testing.assert_array_equal(np.asarray(b["tokens"])[3:],
                                  np.tile(np.asarray(b["tokens"])[:1],
                                          (5, 1)))
    with pytest.raises(ValueError):
        make_request_batch([], 4)
    with pytest.raises(ValueError):
        make_request_batch(rows, 2)


def test_masked_rows_never_perturb_real_rows():
    """Same real rows, different pad-row contents, same bucket: the real
    rows' outputs are byte-identical — the data plane never lets a pad
    row leak into a real row."""
    rt = _mk_rt()
    try:
        key = jax.random.PRNGKey(3)
        rows = make_request_rows(TINY, key, 8)
        real, junk = rows[:3], rows[3:]
        b_pad = make_request_batch(real, 8)          # pads = row-0 copies
        b_junk = make_request_batch(real + junk, 8)  # "pads" = junk rows
        out_pad = np.asarray(rt.run_generic(b_pad))
        out_junk = np.asarray(rt.run_generic(b_junk))
        np.testing.assert_array_equal(out_pad[:3], out_junk[:3])
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# queue: admission control + deadline shedding
# ---------------------------------------------------------------------------

def test_queue_full_rejects_at_submit():
    rt = _mk_rt()
    try:
        clock = FakeClock()
        fe = ServingFrontend(rt, FrontendConfig(capacity=4, max_batch=4),
                             clock=clock)
        rows = make_request_rows(TINY, jax.random.PRNGKey(0), 6)
        reqs = [fe.submit(r) for r in rows]
        assert [r.status for r in reqs] == ["pending"] * 4 + \
            ["rejected"] * 2
        assert reqs[4].done and reqs[4].output is None
        assert rt.stats.requests_submitted == 6
        assert rt.stats.requests_rejected == 2
    finally:
        rt.close()


def test_queue_sheds_deadline_expiring_between_admission_and_take():
    """The admission/take gap, on the queue itself: a request whose
    deadline is comfortably in the future at ``submit`` (so admission
    accepts it) but past by the time the batcher calls ``take`` must
    come back in the *shed* list — and must NOT consume a ``max_n``
    batch slot, so a live request behind it in FIFO order still fills
    the window.  ``now == deadline`` exactly is already late (the
    answer could not be produced in zero time)."""
    clock = FakeClock()
    q = RequestQueue(capacity=8)
    expiring = Request(id=0, payload="a", arrival_ts=clock(),
                       deadline=clock() + 0.05)
    exact = Request(id=1, payload="b", arrival_ts=clock(),
                    deadline=clock() + 0.10)
    live = Request(id=2, payload="c", arrival_ts=clock(),
                   deadline=clock() + 99.0)
    assert q.submit(expiring) and q.submit(exact) and q.submit(live)
    assert len(q) == 3
    clock.advance(0.10)            # expiring now past, exact == now
    ready, shed = q.take(1, clock())
    assert [r.id for r in shed] == [0, 1]
    assert [r.id for r in ready] == [2]    # shed never ate the slot
    assert len(q) == 0
    # shed_expired=False: the policy knob hands even late requests out
    q2 = RequestQueue(capacity=8, shed_expired=False)
    late = Request(id=3, payload="d", arrival_ts=clock(),
                   deadline=clock() - 1.0)
    assert q2.submit(late)
    ready, shed = q2.take(4, clock())
    assert [r.id for r in ready] == [3] and shed == []


def test_deadline_expired_requests_are_shed():
    rt = _mk_rt()
    try:
        clock = FakeClock()
        fe = ServingFrontend(rt, FrontendConfig(capacity=16, max_batch=4,
                                                max_wait_s=0.0),
                             clock=clock)
        rows = make_request_rows(TINY, jax.random.PRNGKey(0), 3)
        late = [fe.submit(r, deadline_s=0.01) for r in rows[:2]]
        live = fe.submit(rows[2], deadline_s=10.0)
        clock.advance(0.02)            # both deadlines now in the past
        n = fe.pump()
        assert n == 1                  # only the live request dispatched
        fe.drain()
        assert [r.status for r in late] == ["shed", "shed"]
        assert late[0].timing["total_s"] == pytest.approx(0.02)
        assert live.status == "ok"
        assert rt.stats.requests_shed == 2
        assert rt.stats.requests_completed == 1
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# mid-serve control update: deopt, no drops, no reorder
# ---------------------------------------------------------------------------

def test_midserve_control_update_keeps_fifo_and_completes_all():
    rt = _mk_rt()
    try:
        fe = ServingFrontend(rt, FrontendConfig(
            capacity=64, max_batch=4, ladder=(4,), window_k_max=1,
            max_wait_s=0.0))
        rows = make_request_rows(TINY, jax.random.PRNGKey(0), 12)
        reqs = [fe.submit(r) for r in rows]
        assert fe.pump() == 4          # first window out the door
        d0 = rt.stats.deopt_steps
        rt.control_update("req_class", {"temperature": np.full(
            TINY.n_classes, 1.3, np.float32)})
        assert fe.drain(timeout=120.0)
        assert [r.status for r in reqs] == ["ok"] * 12
        assert rt.stats.requests_completed == 12
        # the post-update windows ran the generic deopt target
        assert rt.stats.deopt_steps > d0
        # strict FIFO: requests were taken in submission order
        taken = [r._taken_ts for r in reqs]
        assert all(a <= b for a, b in zip(taken, taken[1:]))
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# E2E: open-loop arrivals, byte-identical outputs
# ---------------------------------------------------------------------------

def test_e2e_poisson_outputs_byte_identical_to_one_per_batch():
    """Poisson arrivals through the full queue->batcher->step_many path,
    with a single-slot bucket ladder so every request runs exactly as a
    one-per-batch execution — outputs must match the generic oracle on
    the same single-request batch, byte for byte."""
    rt = _mk_rt()
    try:
        fe = ServingFrontend(rt, FrontendConfig(
            capacity=64, max_batch=1, ladder=(1,), window_k_max=4,
            max_wait_s=1e-4))
        rows = make_request_rows(TINY, jax.random.PRNGKey(7), 24)
        gaps = poisson_gaps(2000.0, 24, seed=1)
        driver = OpenLoopDriver([fe], rows, gaps)
        driver.run()                   # inline: deterministic arrival order
        assert fe.drain(timeout=120.0)
        assert rt.stats.requests_completed == 24
        for r in driver.requests:
            assert r.status == "ok"
            ref = rt.run_generic(make_request_batch([r.payload], 1))
            np.testing.assert_array_equal(np.asarray(r.output),
                                          np.asarray(ref)[0])
            assert set(r.timing) == {"queue_wait_s", "batch_wait_s",
                                     "execute_s", "total_s"}
        # request-latency quantiles flow through the shared histogram
        assert rt.stats.quantile("request_total_s", 0.5) > 0
    finally:
        rt.close()


def test_arrival_generators_hit_target_rate():
    for fn in (poisson_gaps, bursty_onoff_gaps):
        gaps = fn(500.0, 4000, seed=0)
        assert float(np.mean(gaps)) == pytest.approx(1 / 500.0, rel=0.1)


# ---------------------------------------------------------------------------
# BatchShapePass: profile -> (buckets, K) in plan.sites
# ---------------------------------------------------------------------------

def test_batch_shape_pass_selects_from_profile():
    rt = _mk_rt()
    try:
        hist = [0] * 8
        hist[0], hist[3] = 10, 10      # half size-1, half size-4 groups
        rt.attach_profile(StubProfile(_profile_dict(hist, rate=8000.0)))
        rt.recompile(block=True)
        sig_a = rt.plan.signature
        assert plan_batch_shape(rt.plan) == ((1, 4), 4)
        assert BATCH_SHAPE_SITE in dict(rt.plan.sites)
        # the pseudo-site never reaches lookup dispatch: serving works
        b = make_synthetic_batch(TINY, jax.random.PRNGKey(1), 8)
        jax.block_until_ready(rt.step(b))

        # a drifted profile is a genuinely different plan (new signature
        # => new executables => atomic swap), not a mutation in place
        hist2 = [0] * 8
        hist2[7] = 20                  # all groups size 8 now, light rate
        rt.attach_profile(StubProfile(_profile_dict(hist2, rate=100.0)))
        rt.recompile(block=True)
        assert plan_batch_shape(rt.plan) == ((8,), 1)
        assert rt.plan.signature != sig_a
    finally:
        rt.close()


def test_batch_shape_hysteresis_stabilizes_edge_hovering():
    """Traffic hovering at a bucket edge converges to a stable bucket
    superset instead of flipping the plan signature every recompile
    cycle; a regime change (primary moving two or more ladder steps)
    still takes the fresh selection outright."""
    rt = _mk_rt()
    try:
        # sizes 3..5 straddle the 4/8 bucket edge: median fits 4,
        # p95 fits 8 => ((4, 8), 4) at this rate
        edge = [0] * 8
        edge[2], edge[3], edge[4] = 7, 7, 6
        rt.attach_profile(StubProfile(_profile_dict(edge,
                                                    rate=16000.0)))
        rt.recompile(block=True)
        assert plan_batch_shape(rt.plan) == ((4, 8), 4)
        sig = rt.plan.signature

        # the median hovers up past the edge (fresh selection would be
        # ((8,), 3)): bucket 4 still has mass, so the serving superset
        # holds — and the one-step K shrink is damped too.  Signature
        # stable => the revalidation fast path, no swap.
        edge_up = [0] * 8
        edge_up[3], edge_up[4] = 6, 14
        rt.attach_profile(StubProfile(_profile_dict(edge_up,
                                                    rate=12000.0)))
        rt.recompile(block=True)
        assert plan_batch_shape(rt.plan) == ((4, 8), 4)
        assert rt.plan.signature == sig

        # regime change: all size-1 groups at a light rate is a
        # multi-step primary shrink — fresh selection applies, and the
        # abandoned buckets (no observed mass) drop out entirely
        hist1 = [0] * 8
        hist1[0] = 20
        rt.attach_profile(StubProfile(_profile_dict(hist1, rate=100.0)))
        rt.recompile(block=True)
        assert plan_batch_shape(rt.plan) == ((1,), 1)
        assert rt.plan.signature != sig
    finally:
        rt.close()


def test_e2e_batch_shape_selected_from_observed_traffic():
    """Inject a size-4-group arrival pattern; after warmup the recompiled
    plan's bucket set matches the injected distribution."""
    rt = _mk_rt()
    try:
        clock = FakeClock()
        fe = ServingFrontend(rt, FrontendConfig(
            capacity=64, max_batch=8, ladder=(1, 2, 4, 8),
            window_k_max=1, max_wait_s=1e-4), clock=clock)
        key = jax.random.PRNGKey(0)
        for i in range(20):            # 20 groups of exactly 4
            for r in make_request_rows(TINY, jax.random.fold_in(key, i),
                                       4):
                fe.submit(r)
                clock.advance(1e-3)    # 1000 req/s on the virtual clock
            fe.pump()
        fe.drain(timeout=120.0)
        assert rt.stats.requests_completed == 80
        rt.recompile(block=True)
        shape = plan_batch_shape(rt.plan)
        assert shape is not None, "BatchShapePass did not fire"
        buckets, k = shape
        assert buckets == (4,)         # the injected group size's bucket
        assert k == 1                  # 1000 req/s can't fill K>1 windows
        # the batcher reads its shape straight off the swapped plan
        assert fe.batcher.current_shape() == ((4,), 1)
    finally:
        rt.close()


def test_bucket_mispredict_deopts_through_program_guard():
    rt = _mk_rt()
    try:
        clock = FakeClock()
        fe = ServingFrontend(rt, FrontendConfig(
            capacity=64, max_batch=8, ladder=(1, 8), window_k_max=1,
            max_wait_s=0.0, mispredict_window=8, mispredict_deopt=0.4),
            clock=clock)
        # plan buckets = (8,) only — then serve size-1 groups, whose
        # ideal ladder bucket (1) the plan does not offer
        hist = [0] * 8
        hist[7] = 20
        rt.attach_profile(StubProfile(_profile_dict(
            hist, rate=100.0, ladder=(1, 8))))
        rt.recompile(block=True)
        assert plan_batch_shape(rt.plan) == ((8,), 1)
        rt.attach_profile(fe.profile)  # back to the live profile
        v0 = rt.tables.version
        rows = make_request_rows(TINY, jax.random.PRNGKey(2), 20)
        for r in rows:                 # one-at-a-time => size-1 groups
            fe.submit(r)
            clock.advance(1e-3)
            fe.pump()
        fe.drain(timeout=120.0)
        assert rt.stats.shape_mispredicts >= 8
        assert rt.tables.version > v0, "mispredict did not bump version"
        # recompile from the live profile: size-1 groups => bucket 1
        rt.recompile(block=True)
        buckets, _ = plan_batch_shape(rt.plan)
        assert buckets == (1,)
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# step_many on non-example structures + warm_fused
# ---------------------------------------------------------------------------

def test_step_many_serves_bucket_shapes_at_any_k():
    rt = _mk_rt()
    try:
        rows = make_request_rows(TINY, jax.random.PRNGKey(5), 3)
        b = make_request_batch(rows, 4)          # not the example shape
        ref = np.asarray(rt.run_generic(b))
        out1 = np.asarray(rt.step_many([b]))     # K=1, bucket structure
        assert out1.shape[0] == 1
        np.testing.assert_array_equal(out1[0], ref)
        out2 = np.asarray(rt.step_many([b, b]))  # K=2 fused window
        np.testing.assert_array_equal(out2[0], ref)
        np.testing.assert_array_equal(out2[1], ref)
    finally:
        rt.close()


def test_warm_fused_precompiles_every_role():
    """After warm_fused, serving that shape never compiles inline —
    sampled windows (instrumented twin) and deopt windows (generic)
    included."""
    rt = _mk_rt()
    try:
        rows = make_request_rows(TINY, jax.random.PRNGKey(6), 4)
        b = make_request_batch(rows, 4)
        rt.warm_fused([b])
        rt.warm_fused([b, b])
        misses0 = rt.exec_cache.stats.misses
        for _ in range(4):             # crosses the sampling cadence
            rt.step_many([b])
        rt.step_many([b, b])
        rt.control_update("req_class", {"temperature": np.full(
            TINY.n_classes, 1.1, np.float32)})
        rt.step_many([b])              # guard-tripped => generic, warm
        assert rt.exec_cache.stats.misses == misses0
    finally:
        rt.close()
