"""Multi-device sharding + elastic-resize tests.

These run in subprocesses because the placeholder host-device count must
be set before jax initializes (and the main test process must keep seeing
exactly one device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(code: str, devices: int = 8) -> subprocess.CompletedProcess:
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(code))
    return subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, env=ENV,
                          cwd=os.getcwd(), timeout=560)


def test_sharded_train_step_runs_on_debug_mesh():
    r = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import Model, unzip
    from repro.optim import AdamWConfig, init_opt_state
    from repro.launch.steps import make_train_step
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.sharding import make_rules, shardings_for, batch_shardings
    from repro.distributed.meshctx import MeshPolicy, use_policy

    cfg = get_config("phi3.5-moe-42b-a6.6b").smoke()
    model = Model(cfg)
    mesh = make_debug_mesh(2, 2)
    rules = make_rules(False, fsdp=True)
    policy = MeshPolicy(mesh=mesh, batch_axes=("data",), rules=rules)
    with use_policy(policy), mesh:
        pspec = model.init(jax.random.PRNGKey(0))
        params, _ = unzip(pspec)
        opt_pspec = init_opt_state(pspec)
        opt, _ = unzip(opt_pspec)
        state = {"params": params, "opt": opt}
        state_sh = {"params": shardings_for(pspec, mesh, rules),
                    "opt": shardings_for(opt_pspec, mesh, rules)}
        state = jax.device_put(state, state_sh)
        batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
                 "labels": jnp.zeros((4, 16), jnp.int32)}
        step = jax.jit(make_train_step(model, AdamWConfig()),
                       in_shardings=(state_sh, None),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        # expert weights actually sharded over the model axis
        w1 = state["params"]["blocks"]["pos0"]["ffn"]["w1"]
        assert len(w1.sharding.device_set) == 4 or \
            "model" in str(w1.sharding.spec)
        print("OK", loss)
    """)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_elastic_resize_restore(tmp_path):
    """Checkpoint on a (2,2) mesh, restore onto (4,2) — the ZeRO-sharded
    optimizer state reshards on device_put."""
    r = _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import Model, unzip
    from repro.optim import AdamWConfig, init_opt_state
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.sharding import make_rules, shardings_for
    from repro.checkpoint import save, restore

    cfg = get_config("llama3-8b").smoke()
    model = Model(cfg)
    rules = make_rules(False, fsdp=True)

    mesh1 = make_debug_mesh(2, 2)
    pspec = model.init(jax.random.PRNGKey(0))
    params, _ = unzip(pspec)
    sh1 = shardings_for(pspec, mesh1, rules)
    params1 = jax.device_put(params, sh1)
    save({str(tmp_path)!r}, 1, params1)

    mesh2 = make_debug_mesh(4, 2)
    sh2 = shardings_for(pspec, mesh2, rules)
    params2, meta = restore({str(tmp_path)!r}, None, params, shardings=sh2)
    for a, b in zip(jax.tree.leaves(params1), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    leaf = jax.tree.leaves(params2)[3]
    assert len(leaf.sharding.device_set) == 8
    print("OK elastic")
    """)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK elastic" in r.stdout


def test_moe_sharded_matches_local():
    """EP all-to-all shard_map MoE == single-device dropless oracle."""
    r = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.moe import moe_ffn_local, moe_ffn_sharded
    from repro.models.config import MoEConfig
    from repro.models.params import Initializer, unzip
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.meshctx import MeshPolicy, use_policy

    moe = MoEConfig(num_experts=4, top_k=2, expert_d_ff=32,
                    capacity_factor=4.0)   # high cf => no drops
    ini = Initializer(jax.random.PRNGKey(0), dtype=jnp.float32)
    d = 16
    params = {
        "w_router": ini.normal((d, 4), (None, None), dtype=jnp.float32),
        "b_router": ini.zeros((4,), (None,), dtype=jnp.float32),
        "w1": ini.normal((4, d, 32), (None, None, None)),
        "w3": ini.normal((4, d, 32), (None, None, None)),
        "w2": ini.normal((4, 32, d), (None, None, None)),
    }
    params = {k: v.value for k, v in params.items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (64, d), jnp.float32)

    y_local, m_local = moe_ffn_local(params, x, moe)

    mesh = make_debug_mesh(2, 2)
    policy = MeshPolicy(mesh=mesh, batch_axes=("data",))
    with use_policy(policy), mesh:
        y_sh, m_sh = moe_ffn_sharded(params, x, moe)
    err = float(jnp.abs(y_local - y_sh).max())
    drops = float(m_sh["dropped"])
    assert drops == 0.0, drops
    assert err < 1e-4, err
    print("OK moe", err)
    """)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK moe" in r.stdout


def test_gqa_seq_parallel_decode_matches_reference():
    """Sequence-parallel flash decode == single-device blocked attention."""
    r = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.attention import attend_blocked, _gqa_decode_seq_parallel
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.meshctx import MeshPolicy

    key = jax.random.PRNGKey(0)
    B, Sk, H, Hkv, D = 4, 64, 8, 2, 16
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, D))
    kv_pos = jnp.arange(Sk, dtype=jnp.int32)
    positions = jnp.array([Sk - 1], jnp.int32)

    ref = attend_blocked(q, k, v, q_pos=positions, kv_pos=kv_pos,
                         causal=True, block=16)
    mesh = make_debug_mesh(2, 4)
    pol = MeshPolicy(mesh=mesh, batch_axes=("data",))
    with mesh:
        out = _gqa_decode_seq_parallel(pol, q, k, v, kv_pos, positions,
                                       window=None, logit_softcap=0.0)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, err
    # windowed variant
    ref_w = attend_blocked(q, k, v, q_pos=positions, kv_pos=kv_pos,
                           causal=True, window=20, block=16)
    with mesh:
        out_w = _gqa_decode_seq_parallel(pol, q, k, v, kv_pos, positions,
                                         window=20, logit_softcap=0.0)
    err_w = float(jnp.abs(out_w - ref_w).max())
    assert err_w < 1e-5, err_w
    print("OK gqa-sp", err, err_w)
    """)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK gqa-sp" in r.stdout


def test_hlo_analyzer_counts_collectives():
    """The roofline's collective term comes from the HLO parser — verify
    it sees a known psum's bytes on a real multi-device compile."""
    r = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compat import shard_map
    from repro.launch.mesh import make_debug_mesh
    from repro.launch import hlo_analysis as H

    mesh = make_debug_mesh(4, 2)
    def f(x):
        def body(xl):
            return jax.lax.psum(xl, "data")
        return shard_map(body, mesh=mesh, in_specs=P("data", None),
                         out_specs=P(None, None))(x)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    with mesh:
        txt = jax.jit(f).lower(x).compile().as_text()
    ana = H.analyze(txt)
    # per-device operand: (64/4) x 128 x 4B = 8192 bytes
    assert ana["collective_bytes"] >= 8192, ana["collective_bytes"]
    assert ana["per_collective"]["all-reduce"] >= 8192
    print("OK analyzer", ana["collective_bytes"])
    """)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK analyzer" in r.stdout
