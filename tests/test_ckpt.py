"""Checkpoint layer tests: atomic two-rename swap (the save crash
window), stale-writer GC, retention, async error propagation, bf16
integer-view round-trip, elastic reshard onto shrunk AND grown meshes,
and exact lr-schedule / data-stream position on resume."""
import json
import os
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointHandle, latest_step, restore,
                              save, save_async)

ENV = {**os.environ, "PYTHONPATH": "src"}


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16) * 1.5,
            "step": jnp.asarray(7, jnp.int32)}


# ---- bf16 / ml_dtypes integer-view round-trip ---------------------------

def test_bf16_roundtrip_bitwise(tmp_path):
    """npz cannot store ml_dtypes natively; the integer-view detour must
    round-trip every bit pattern — including NaN payloads and denormals,
    which a float cast would destroy."""
    import ml_dtypes
    patterns = np.arange(0, 2**16, 7, dtype=np.uint16)  # spread of bf16
    tree = {"x": jnp.asarray(patterns.view(ml_dtypes.bfloat16)),
            "f8": jnp.asarray(
                np.arange(0, 256, 3, dtype=np.uint8).view(
                    ml_dtypes.float8_e4m3fn))}
    save(tmp_path, 1, tree)
    out, _ = restore(tmp_path, 1, tree)
    assert out["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["x"]).view(np.uint16), patterns)
    np.testing.assert_array_equal(
        np.asarray(out["f8"]).view(np.uint8),
        np.asarray(tree["f8"]).view(np.uint8))


def test_meta_and_values_roundtrip(tmp_path):
    tree = _tree()
    save(tmp_path, 3, tree, meta={"lr": 0.125, "arch": "t"})
    out, meta = restore(tmp_path, None, tree)
    assert meta["step"] == 3 and meta["lr"] == 0.125
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---- the save crash window ----------------------------------------------

def test_overwrite_never_destroys_only_copy(tmp_path, monkeypatch):
    """The old scheme did rmtree(final) BEFORE renaming the tmp dir in:
    a crash between the two left ZERO copies.  The two-rename swap must
    keep a complete copy on disk at every instant — simulate the worst
    crash point by failing the tmp->final rename and check the original
    checkpoint is still restorable."""
    tree = _tree()
    save(tmp_path, 5, tree)

    real_rename = os.rename

    def exploding_rename(src, dst):
        if Path(src).name.startswith(".tmp_"):
            raise OSError("simulated crash mid-swap")
        return real_rename(src, dst)

    monkeypatch.setattr(os, "rename", exploding_rename)
    with pytest.raises(OSError, match="mid-swap"):
        save(tmp_path, 5, {"w": jnp.zeros((3, 4)),
                           "b": jnp.zeros((4,), jnp.bfloat16),
                           "step": jnp.asarray(0, jnp.int32)})
    monkeypatch.undo()

    # the interrupted writer left litter; GC must recover a COMPLETE
    # copy of step 5 — old content (set aside) or new (complete tmp)
    assert latest_step(tmp_path) == 5
    out, _ = restore(tmp_path, 5, tree)
    assert np.asarray(out["step"]) in (0, 7)    # a complete copy, not mix
    # and the litter is gone
    assert not list(Path(tmp_path).glob(".tmp_*"))
    assert not list(Path(tmp_path).glob(".old_*"))


def test_gc_promotes_complete_orphan(tmp_path):
    """A crash after tmp completion but before the swap leaves a
    complete .tmp_<N> and no step_<N>: GC promotes it (the write is
    finished, not discarded)."""
    tree = _tree()
    save(tmp_path, 2, tree)
    os.rename(tmp_path / "step_2", tmp_path / ".tmp_9")
    assert latest_step(tmp_path) == 9
    out, meta = restore(tmp_path, 9, tree)
    assert meta["step"] == 2          # manifest content survived intact
    assert np.asarray(out["step"]) == 7


def test_gc_deletes_incomplete_orphan(tmp_path):
    """A .tmp_<N> without manifest.json (writer died mid-npz) is
    garbage, never promoted."""
    tree = _tree()
    save(tmp_path, 1, tree)
    half = tmp_path / ".tmp_4"
    half.mkdir()
    (half / "arrays.npz").write_bytes(b"truncated")
    assert latest_step(tmp_path) == 1
    assert not half.exists()


def test_gc_prefers_tmp_over_old(tmp_path):
    """Crash between the two renames: step_<N> was set aside to
    .old_<N> and the complete .tmp_<N> never swapped in.  GC must
    promote the NEWER content (.tmp) and drop .old."""
    tree = _tree()
    save(tmp_path, 6, tree)
    os.rename(tmp_path / "step_6", tmp_path / ".old_6")
    save(tmp_path, 6, {"w": jnp.zeros((3, 4)),
                       "b": jnp.zeros((4,), jnp.bfloat16),
                       "step": jnp.asarray(99, jnp.int32)})
    os.rename(tmp_path / "step_6", tmp_path / ".tmp_6")
    assert latest_step(tmp_path) == 6
    out, _ = restore(tmp_path, 6, tree)
    assert np.asarray(out["step"]) == 99
    assert not (tmp_path / ".old_6").exists()


# ---- retention ----------------------------------------------------------

def test_keep_last_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4):
        save(tmp_path, s, tree, keep_last=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    # default keeps everything
    for s in (5, 6):
        save(tmp_path, s, tree)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4, 5, 6]


# ---- async handle -------------------------------------------------------

def test_save_async_returns_handle(tmp_path):
    tree = _tree()
    h = save_async(tmp_path, 11, tree, meta={"k": 1}, keep_last=3)
    assert isinstance(h, CheckpointHandle)
    path = h.join(timeout=60)
    assert path is not None and path.endswith("step_11")
    assert h.done() and h.path() == path
    assert latest_step(tmp_path) == 11


def test_save_async_error_reraised_on_join(tmp_path):
    """A failed background write (here: the target is a FILE, so mkdir
    explodes) must re-raise on join() — the trainer fails loudly
    instead of believing it checkpointed."""
    target = tmp_path / "ckpt"
    target.write_text("not a directory")
    h = save_async(str(target), 1, _tree())
    with pytest.raises(OSError):
        h.join(timeout=60)
    assert h.done() and h.path() is None


# ---- elastic reshard: shrink AND grow -----------------------------------

def _run_subprocess(code: str, devices: int = 8):
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(code))
    return subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, env=ENV,
                          cwd=os.getcwd(), timeout=560)


def test_elastic_reshard_shrink_and_grow(tmp_path):
    """A checkpoint taken on a 4-device mesh restores bitwise onto a
    2-device mesh (device loss) AND onto an 8-device mesh (grow-back),
    with the leaves actually laid out on the new device sets."""
    r = _run_subprocess(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save
    from repro.distributed.fault import elastic_reshard

    def mesh_over(n):
        return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("data",))

    tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
             "b": (jnp.arange(8, dtype=jnp.float32) / 3).astype(
                 jnp.bfloat16)}}
    sh4 = NamedSharding(mesh_over(4), P("data"))
    placed = jax.device_put(tree, {{k: sh4 for k in tree}})
    save({str(tmp_path)!r}, 1, placed)

    for n in (2, 8):                     # shrink, then grow
        shn = NamedSharding(mesh_over(n), P("data"))
        out, meta = elastic_reshard({str(tmp_path)!r}, tree,
                                    {{k: shn for k in tree}})
        assert meta["step"] == 1
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(out[k], np.float32),
                np.asarray(tree[k], np.float32))
            assert len(out[k].sharding.device_set) == n, (k, n)
    print("OK reshard")
    """)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK reshard" in r.stdout


# ---- resume position: lr schedule + data stream -------------------------

def test_resume_restores_lr_step_and_pipeline_position(tmp_path):
    """After resume, the NEXT optimizer update must use the exact lr the
    uninterrupted run would have used (the schedule is driven by the
    checkpointed opt.step, not a fresh counter), and the data pipeline
    must emit the exact next batch of the stream."""
    from repro.data import DataConfig, TokenPipeline
    from repro.optim import AdamWConfig, adamw_update

    cfg = AdamWConfig(lr=1e-2, warmup_steps=3, total_steps=10)
    params = {"w": jnp.ones((4, 4), jnp.float32)}

    def opt0():
        return {"master": jax.tree.map(lambda p: p.astype(jnp.float32),
                                       params),
                "m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    grads = {"w": jnp.full((4, 4), 0.25, jnp.float32)}

    # uninterrupted: 6 updates, record lr of update 6
    p, opt = dict(params), opt0()
    for _ in range(6):
        p, opt, metrics = adamw_update(cfg, grads, opt)
    lr_ref = float(metrics["lr"])
    w_ref = np.asarray(p["w"])

    # interrupted at 5, checkpointed, resumed, one more update
    p, opt = dict(params), opt0()
    for _ in range(5):
        p, opt, _ = adamw_update(cfg, grads, opt)
    save(tmp_path, 5, {"params": p, "opt": opt})
    restored, _ = restore(tmp_path, 5, {"params": p, "opt": opt})
    assert int(np.asarray(restored["opt"]["step"])) == 5
    p2, opt2, metrics2 = adamw_update(cfg, grads, restored["opt"])
    assert float(metrics2["lr"]) == lr_ref
    np.testing.assert_array_equal(np.asarray(p2["w"]), w_ref)

    # pipeline position: stream resumes at the exact next batch
    dcfg = DataConfig(vocab=64, seq=8, global_batch=2, seed=3)
    ref_pipe = TokenPipeline(dcfg)
    for _ in range(5):
        ref_pipe.next_batch()
    sixth = ref_pipe.next_batch()

    pipe = TokenPipeline(dcfg)
    for _ in range(5):
        pipe.next_batch()
    sd = pipe.state_dict()
    resumed = TokenPipeline(dcfg)
    resumed.load_state_dict(sd)
    got = resumed.next_batch()
    for k in sixth:
        np.testing.assert_array_equal(np.asarray(sixth[k]),
                                      np.asarray(got[k]))
    # peek does not advance the stream
    resumed.load_state_dict(sd)
    peeked = resumed.peek_batch()
    np.testing.assert_array_equal(np.asarray(peeked["tokens"]),
                                  np.asarray(sixth["tokens"]))
    assert resumed.state_dict() == sd
