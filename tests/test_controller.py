"""The `repro.core.controller` subsystem: one adaptive controller
driving N data planes.

Covers the PR's acceptance criteria: a controller-shared fleet plans
identically to standalone runtimes for the same traffic; the sampling
duty cycle backs off (and the instrumented twin is swapped out) after K
stable cycles and re-arms on a control update; the recompile scheduler
never runs two cycles for one plane concurrently and orders pending
planes by staleness x traffic; `close()` tears every worker down while
the data planes keep serving; instrumentation snapshots are taken
without the runtime lock; `RuntimeStats` counters are atomic and
aggregated by `controller.stats()`.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, EngineConfig, \
    MorpheusController, MorpheusRuntime, RuntimeStats, SketchConfig, \
    Table, TableSet
from repro.core.controller import RecompileScheduler


def _user_step(params, ctx, batch):
    row = ctx.lookup("classes", batch["cls"], fields=("scale",))
    x = batch["x"] * row["scale"][:, None]
    if ctx.flag("boost", default=False):
        x = x + 1.0
    return x


def _scales(n, seed=0):
    return np.linspace(1.0, 2.0, n).astype(np.float32) + seed


N_VALID = 48      # > max_inline => the lookup site is instrumented


def _tables(seed=0):
    return TableSet([Table("classes", {"scale": _scales(N_VALID, seed)},
                           n_valid=N_VALID, instrument=True)])


def _batch():
    """Skewed deterministic traffic: 75% of lookups hit classes {0,1,2},
    so the traffic fast-path pass has a hot set to find."""
    cls = np.arange(16) % N_VALID
    cls[:12] = np.arange(12) % 3
    return {"cls": jnp.asarray(cls, jnp.int32),
            "x": jnp.ones((16, 4), jnp.float32)}


def _mk(controller=None, seed=0, plane_id=None, sample_every=2):
    cfg = EngineConfig(sketch=SketchConfig(sample_every=sample_every,
                                           max_hot=4, hot_coverage=0.5))
    return MorpheusRuntime(_user_step, _tables(seed), None, _batch(),
                           cfg=cfg, controller=controller,
                           plane_id=plane_id)


# ---------------------------------------------------------------------------
# fleet plan parity (acceptance criterion)
# ---------------------------------------------------------------------------

def test_fleet_plans_match_standalone():
    """4 runtimes sharing one controller must plan byte-identically to 4
    standalone runtimes for the same traffic — the controller changes
    who schedules/owns the loop, never what gets planned."""
    ctl = MorpheusController(ControllerConfig(workers=2))
    shared = [_mk(ctl, seed=i) for i in range(4)]
    solo = [_mk(seed=i) for i in range(4)]
    try:
        assert all(rt.exec_cache is ctl.exec_cache for rt in shared)
        for rt in shared + solo:
            for _ in range(6):
                rt.step(_batch())
        # fleet: cycles through the controller's bounded worker pool;
        # standalone: classic blocking recompiles
        assert ctl.schedule_all() == 4
        assert ctl.drain(timeout=120)
        assert ctl.scheduler.stats()["completed"] == 4
        for rt in solo:
            rt.recompile(block=True)
        for a, b in zip(shared, solo):
            assert a.plan.label.startswith("specialized")
            assert a.plan.sites == b.plan.sites
            assert a.plan.flags == b.plan.flags
            assert a.plan.signature == b.plan.signature
            np.testing.assert_allclose(np.asarray(a.step(_batch())),
                                       np.asarray(b.step(_batch())),
                                       rtol=1e-6)
    finally:
        ctl.close()
        for rt in solo:
            rt.close()


def test_runtime_owns_no_snapshot_worker():
    """The refactor's structural criterion: the snapshot worker lives on
    the controller, not the runtime."""
    rt = _mk()
    try:
        assert not hasattr(rt, "_snapshot_worker")
        rt.step(_batch())
        rt.recompile(block=True)
        w = rt.snapshot_worker
        assert rt.controller._workers[rt.plane_id] is w
        assert rt.last_snapshot.thread_ident == w._thread.ident
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# adaptive sampling: back-off, disarm, re-arm
# ---------------------------------------------------------------------------

def test_sampling_backs_off_then_disarms_and_rearms():
    rt = _mk()
    K = rt.sampler.disarm_after
    try:
        for _ in range(6):
            rt.step(_batch())
        rt.recompile(block=True)          # generic -> specialized: churn
        assert rt.sampler.armed
        e0 = rt.sampler.sample_every
        for _ in range(K):                # K consecutive stable cycles
            rt.step(_batch())
            rt.recompile(block=True)
        # cadence backed off while armed, then the twin was swapped out
        assert not rt.sampler.armed
        assert rt.sampler.duty_cycle() == 0.0
        assert rt.state.instr == {}           # no sketches in the state
        assert rt.instr_exec is rt.exec       # twin IS the specialized
        # ...but the specialization survives: disarmed cycles plan from
        # the profile retained at the last sampled window
        assert rt.plan.label.startswith("specialized")
        sig = rt.plan.signature
        assert any(s.impl == "hot_cache" for _, s in rt.plan.sites)
        i0 = rt.stats.instr_steps
        for _ in range(8):
            rt.step(_batch())
        assert rt.stats.instr_steps == i0     # zero instrumentation cost
        info = rt.recompile(block=True)       # disarmed cycles revalidate
        assert info["revalidated"] is True
        assert rt.plan.signature == sig
        # control update -> re-arm: cadence restored, twin reinstalled
        rt.control_update("classes", {"scale": _scales(N_VALID, 1)})
        assert rt.sampler.armed
        assert rt.sampler.sample_every <= e0
        rt.recompile(block=True)
        assert "classes#0" in rt.state.instr
        assert rt.instr_exec is not rt.exec
        assert rt.sampler.duty_cycle() > 0.0
        s0 = rt.stats.instr_steps
        for _ in range(4):
            rt.step(_batch())
        assert rt.stats.instr_steps > s0      # sampling again
    finally:
        rt.close()


def test_pinned_sampler_never_disarms():
    rt = _mk()
    try:
        rt.sampler.pin(2)
        for _ in range(4):
            rt.step(_batch())
        for _ in range(8):                    # way past disarm_after
            rt.recompile(block=True)
        assert rt.sampler.armed
        assert rt.sampler.sample_every == 2
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# recompile scheduler
# ---------------------------------------------------------------------------

class _StubPlane:
    def __init__(self, name, prio, log, started=None, gate=None):
        self._name, self._prio, self._log = name, prio, log
        self._started, self._gate = started, gate

    def recompile_priority(self):
        return self._prio

    def _recompile_now(self):
        if self._started is not None:
            self._started.set()
        if self._gate is not None:
            assert self._gate.wait(timeout=10)
        self._log.append(self._name)


def test_scheduler_priority_and_coalescing():
    """With one worker busy, queued planes run in staleness x traffic
    priority order, and re-submitting a pending plane coalesces."""
    sched = RecompileScheduler(workers=1)
    log, started, gate = [], threading.Event(), threading.Event()
    blocker = _StubPlane("blocker", 1.0, log, started, gate)
    lo = _StubPlane("lo", 1.0, log)
    hi = _StubPlane("hi", 100.0, log)
    try:
        assert sched.submit("blocker", blocker) is True
        assert started.wait(timeout=10)       # worker busy on blocker
        assert sched.submit("lo", lo) is True
        assert sched.submit("hi", hi) is True
        assert sched.submit("lo", lo) is False          # coalesced
        gate.set()
        assert sched.drain(timeout=10)
        assert log == ["blocker", "hi", "lo"]
        st = sched.stats()
        assert st["scheduled"] == 3 and st["coalesced"] == 1
        assert st["completed"] == 3 and st["workers"] == 1
    finally:
        sched.close()


def test_scheduler_survives_a_failing_plane():
    sched = RecompileScheduler(workers=1)
    log = []

    class _Bad:
        def recompile_priority(self):
            return 1.0

        def _recompile_now(self):
            raise RuntimeError("boom")

    bad, ok = _Bad(), _StubPlane("ok", 1.0, log)   # the scheduler holds
    try:                                           # weakrefs: keep these
        sched.submit("bad", bad)                   # alive ourselves
        sched.submit("ok", ok)
        assert sched.drain(timeout=10)
        assert log == ["ok"]
        st = sched.stats()
        assert st["failed"] == 1 and st["completed"] == 1
        assert isinstance(sched.last_error, RuntimeError)
    finally:
        sched.close()


def test_scheduler_never_overlaps_cycles_for_one_plane():
    """Hammer one plane with scheduled cycles from a 4-worker pool while
    the control plane churns: the pool must never run two cycles for the
    same plane concurrently."""
    ctl = MorpheusController(ControllerConfig(workers=4))
    rt = _mk(ctl)
    lk = threading.Lock()
    active, max_active = [0], [0]
    orig = rt._recompile_now

    def wrapped():
        with lk:
            active[0] += 1
            max_active[0] = max(max_active[0], active[0])
        try:
            time.sleep(0.005)
            return orig()
        finally:
            with lk:
                active[0] -= 1

    rt._recompile_now = wrapped
    try:
        for i in range(10):
            rt.control_update("classes", {"scale": _scales(N_VALID, i)})
            ctl.schedule(rt)
            rt.step(_batch())
        assert ctl.drain(timeout=120)
        assert max_active[0] == 1
        assert ctl.scheduler.stats()["completed"] >= 1
        assert ctl.scheduler.stats()["running"] == 0
    finally:
        ctl.close()


def test_recompile_priority_orders_stale_hot_planes_first():
    ctl = MorpheusController()
    a, b = _mk(ctl), _mk(ctl)
    try:
        for _ in range(10):
            a.step(_batch())
        a.tables.bump_version("drift")
        a.tables.bump_version("drift")
        assert a.recompile_priority() > b.recompile_priority()
    finally:
        ctl.close()


# ---------------------------------------------------------------------------
# teardown
# ---------------------------------------------------------------------------

def test_controller_close_tears_down_workers_cleanly():
    ctl = MorpheusController(ControllerConfig(workers=2))
    rt = _mk(ctl)
    rt.step(_batch())
    rt.recompile(block=True)                # creates the snapshot worker
    worker_thread = rt.snapshot_worker._thread
    ctl.schedule(rt)
    assert ctl.drain(timeout=120)
    pool_threads = list(ctl.scheduler._threads)
    assert pool_threads
    ctl.close()
    assert not worker_thread.is_alive()
    assert all(not t.is_alive() for t in pool_threads)
    with pytest.raises(RuntimeError):
        rt.recompile(block=True)            # no silent resurrection
    with pytest.raises(RuntimeError):
        ctl.schedule(rt)
    out = rt.step(_batch())                 # the data plane keeps serving
    assert np.isfinite(np.asarray(out)).all()
    ctl.close()                             # idempotent


def test_closed_runtime_gc_does_not_unregister_replacement_plane():
    """close() must detach the GC finalizer: a dead runtime's later GC
    must not tear down a NEW plane registered under the same plane_id."""
    import gc
    ctl = MorpheusController()
    rt1 = _mk(ctl, plane_id="p")
    rt1.close()
    rt2 = _mk(ctl, plane_id="p")        # the id is free again
    del rt1
    gc.collect()
    try:
        assert "p" in ctl.planes()
        rt2.step(_batch())
        assert rt2.recompile(block=True) is not None
    finally:
        ctl.close()


def test_cache_miss_accounting_counts_each_compile_once():
    """The runtime probes before routing misses through get_or_compile —
    each compiled executable must register exactly one cache miss."""
    rt = _mk()
    try:
        rt.step(_batch())
        rt.recompile(block=True)
        s = rt.exec_cache.stats
        assert s.misses == s.inserts
    finally:
        rt.close()


def test_runtime_close_detaches_only_its_plane():
    ctl = MorpheusController()
    a, b = _mk(ctl, seed=0), _mk(ctl, seed=1)
    try:
        for rt in (a, b):
            rt.step(_batch())
        a.recompile(block=True)
        a.close()                           # shared controller survives
        with pytest.raises(RuntimeError):
            a.recompile(block=True)
        assert b.recompile(block=True) is not None
        assert a.plane_id not in ctl.planes()
        assert b.plane_id in ctl.planes()
    finally:
        ctl.close()


# ---------------------------------------------------------------------------
# lock-free instrumentation snapshots (double buffer)
# ---------------------------------------------------------------------------

def test_instr_snapshot_taken_without_runtime_lock():
    """The acceptance criterion for the double-buffered sketches: the
    host readout completes while another thread holds the runtime lock
    (i.e. mid-step), because it reads the published back buffer."""
    rt = _mk()
    try:
        seq0 = rt._backbuf.seq
        for _ in range(4):
            rt.step(_batch())
        assert rt._backbuf.seq > seq0       # sampled steps published
        got = {}

        def reader():
            got["snap"] = rt._host_instr_snapshot()

        with rt._lock:                      # the serving critical section
            th = threading.Thread(target=reader)
            th.start()
            th.join(timeout=10)
            assert not th.is_alive(), \
                "_host_instr_snapshot blocked on the runtime lock"
        snap = got["snap"]
        assert "classes#0" in snap
        assert int(snap["classes#0"]["total"]) > 0
    finally:
        rt.close()


def test_back_buffer_tracks_recorded_traffic():
    """The back buffer is not an approximation: sketches only advance on
    sampled steps, each of which republishes — so the snapshot's hot
    keys match the traffic."""
    rt = _mk()
    try:
        for _ in range(8):
            rt.step(_batch())
        snap = rt._host_instr_snapshot()
        from repro.core import instrument
        hot, cov, total = instrument.hot_keys(
            snap["classes#0"], rt.engine.cfg.sketch)
        assert set(hot[:3].tolist()) == {0, 1, 2}
        assert total > 0
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# atomic stats + fleet aggregation
# ---------------------------------------------------------------------------

def test_runtime_stats_counters_are_atomic():
    st = RuntimeStats()

    def w():
        for _ in range(2000):
            st.bump(steps=1, cache_hits=2)
            st.log("t1_history", 0.0)

    ths = [threading.Thread(target=w) for _ in range(8)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert st.steps == 16000
    assert st.cache_hits == 32000
    assert len(st.t1_history) == 16000
    snap = st.snapshot()
    assert snap["steps"] == 16000
    assert snap["t1_history"] is not st.t1_history   # a copy


def test_controller_stats_aggregates_across_planes():
    ctl = MorpheusController()
    a, b = _mk(ctl, plane_id="a"), _mk(ctl, plane_id="b")
    try:
        for _ in range(3):
            a.step(_batch())
            b.step(_batch())
        a.recompile(block=True)
        s = ctl.stats()
        assert set(s.planes) == {"a", "b"}
        assert s.totals["steps"] == a.stats.steps + b.stats.steps == 6
        assert s.totals["recompiles"] == 1
        assert s.sampling["a"]["armed"] is True
        assert 0.0 <= s.sampling["a"]["duty_cycle"] <= 1.0
        assert 0.0 <= s.cache_hit_rate <= 1.0
        assert s.scheduler["workers"] == 0    # pool spawns lazily
    finally:
        ctl.close()
