"""End-to-end behaviour tests for the paper's headline claims, at
miniature scale on CPU:

  1. dynamic specialization beats the statically-compiled data plane
     under skewed traffic (Fig 5);
  2. specialization NEVER changes semantics (the eBPF-verifier safety
     story: guards + exact fast paths);
  3. control-plane updates deopt immediately (program-level guard) and
     recompilation re-converges (Fig 10);
  4. traffic drift re-targets the hot set (unsupervised adaptation).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, MorpheusRuntime, SketchConfig
from repro.serving import ServeConfig, build_params, build_tables, \
    make_synthetic_batch, make_serve_step


@pytest.fixture(scope="module")
def system():
    cfg = ServeConfig()
    key = jax.random.PRNGKey(0)
    params = build_params(cfg, key)
    for lp in params["layers"]:
        bias = np.zeros(cfg.n_experts, np.float32)
        bias[:3] = 6.0
        lp["moe"]["b_router"] = jnp.asarray(bias)
    # per-class temperatures vary: the class table is NOT constant, so
    # the traffic-dependent fast path (not const-prop) is what fires
    tables = build_tables(cfg, key, uniform_temperature=False)
    rt = MorpheusRuntime(
        make_serve_step(cfg), tables, params,
        make_synthetic_batch(cfg, key),
        cfg=EngineConfig(
            sketch=SketchConfig(sample_every=2, max_hot=4,
                                hot_coverage=0.6),
            features={"vision_enabled": False, "track_sessions": True},
            moe_router_table="router"))
    return cfg, rt


def _median_step_time(rt, cfg, n=30, seed0=100):
    ts = []
    for i in range(n):
        b = make_synthetic_batch(cfg, jax.random.PRNGKey(seed0 + i), 8,
                               "high")
        t0 = time.time()
        jax.block_until_ready(rt.step(b))
        ts.append(time.time() - t0)
    return float(np.median(ts))


def test_specialization_speeds_up_skewed_traffic(system):
    cfg, rt = system
    t_generic = _median_step_time(rt, cfg)
    rt.recompile(block=True)
    assert rt.hot_experts() is not None, "hot experts not detected"
    t_spec = _median_step_time(rt, cfg)
    assert t_spec < t_generic * 0.85, (
        f"expected >=15% speedup, got {t_generic/t_spec:.2f}x")


def test_specialization_is_semantics_preserving(system):
    cfg, rt = system
    rt.recompile(block=True)
    b = make_synthetic_batch(cfg, jax.random.PRNGKey(4242), 8, "high")
    out_s = rt.step(b)
    out_g = rt.run_generic(b)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_g),
                               rtol=1e-4, atol=1e-4)


def test_control_plane_update_deopt_and_recover(system):
    cfg, rt = system
    rt.recompile(block=True)
    d0 = rt.stats.deopt_steps
    rt.control_update("req_class", {"temperature": np.full(
        cfg.n_classes, 1.7, np.float32)})
    b = make_synthetic_batch(cfg, jax.random.PRNGKey(7), 8, "high")
    out_deopt = rt.step(b)
    assert rt.stats.deopt_steps == d0 + 1
    rt.recompile(block=True)
    out_spec = rt.step(b)
    np.testing.assert_allclose(np.asarray(out_deopt),
                               np.asarray(out_spec), rtol=1e-4, atol=1e-4)


def test_unsupervised_adaptation_to_drift(system):
    cfg, rt = system
    # earlier tests let the adaptive sampler back off; pin the cadence
    rt.sampler.pin(2)
    # ...and the control-plane test made temperatures CONSTANT, which
    # (correctly) promotes const-prop over the fast path — re-diversify
    rng = np.random.default_rng(1)
    rt.control_update("req_class", {"temperature": rng.uniform(
        0.5, 1.5, cfg.n_classes).astype(np.float32)})
    # phase A traffic
    for i in range(12):
        rt.step(make_synthetic_batch(cfg, jax.random.PRNGKey(i), 8, "high",
                                   hot_offset=0))
    rt.recompile(block=True)
    plan_a = rt.plan.sites
    # drift: new hot classes/tokens
    for i in range(12):
        rt.step(make_synthetic_batch(cfg, jax.random.PRNGKey(500 + i), 8,
                                   "high", hot_offset=17))
    rt.recompile(block=True)
    plan_b = rt.plan.sites

    def hot_of(sites, table):
        return [s.hot_keys for sid, s in sites
                if sid.startswith(table) and s.impl == "hot_cache"]
    # the request-class hot set must have moved with the traffic
    # (vocab hot tokens are too uniform within the hot window to qualify
    # for a fast path — the class table is the discriminative one)
    a, b = hot_of(plan_a, "req_class"), hot_of(plan_b, "req_class")
    assert b, f"no fast path planned after drift: {plan_b}"
    assert a != b, f"hot set did not move: {a} vs {b}"
