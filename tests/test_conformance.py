"""Arch-zoo conformance: differential specialized-vs-generic oracle.

The tentpole matrix drives every architecture in ``ARCH_IDS`` through a
seeded ≥50-event churn schedule (control-table updates, flag flips,
hot-set rotations, sampler churn, fused-window boundaries, injected
mispredicts) while a lock-stepped generic oracle replays the identical
batch/update sequence, asserting **byte-identical** outputs and table
state at every comparison point, plus per-arch specialization coverage
(SSD fast path on mamba2/jamba, MoE fast path on the MoE archs,
cross-attention/media table specialization on seamless/pixtral) and a
guard-observable deopt after every injected mispredict — all enforced
inside :func:`repro.testing.run_conformance`.

The full 10 arch x 3 serving-mode matrix costs ~15 min on CPU, so
tier-1 runs a representative QUICK subset by default; the CI
``conformance`` job sets ``CONFORMANCE_FULL=1`` and shards the full
matrix per-arch with ``pytest -k <arch>`` (cell ids are
``<arch>-<mode>``, so ``-k mamba2`` selects all three modes of one
arch).

The determinism cell spawns a SECOND python process with a different
``PYTHONHASHSEED`` and asserts the planned signature fingerprints match
the in-process run — plan identity must be a pure function of control
state + traffic, never of process-local hash salts or dict order.
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.configs import ARCH_IDS
from repro.testing import (build_plane, generate_schedule,
                           register_churn_move, run_chaos,
                           run_conformance, run_fingerprints)
from repro.testing.chaos import CHAOS_MODES, FAULT_KINDS
from repro.testing.churn import _MOVES, ChurnEvent, churn_moves
from repro.testing.conformance import MODES

FULL = os.environ.get("CONFORMANCE_FULL", "") == "1"

# The tier-1 subset: every specialization family (dense inline/one-hot/
# hot-cache, MoE fast path, SSD fast path, cross-attention source
# tables) and every serving mode appears at least once.
QUICK = (
    ("llama3-8b", "plain"),
    ("mamba2-1.3b", "plain"),
    ("phi3.5-moe-42b-a6.6b", "fused"),
    ("seamless-m4t-medium", "frontend"),
)

CELLS = (tuple((a, m) for a in ARCH_IDS for m in MODES)
         if FULL else QUICK)


@pytest.mark.parametrize(
    "arch,mode", CELLS, ids=[f"{a}-{m}" for a, m in CELLS])
def test_conformance_cell(arch, mode):
    report = run_conformance(arch, mode, seed=0, n_events=60)
    # run_conformance already raised on any divergence / coverage gap /
    # un-deopted mispredict; the report just proves the run had teeth.
    assert report["events"] >= 50
    assert report["steps"] >= 30
    assert report["compares"] >= 10
    assert report["recompiles"] >= 3
    assert report["mispredicts"] >= 2
    assert report["deopt_steps"] >= report["mispredicts"]
    assert report["signature"]
    specialized = [(t, i) for t, i in report["impls_seen"]
                   if i != "gather"]
    assert specialized, report["impls_seen"]


# ---------------------------------------------------------------------------
# chaos: fault-injected degraded-mode serving vs the generic oracle
# ---------------------------------------------------------------------------

# The tier-1 chaos subset: both chaos serving modes on the quick arch.
# Full CI (CONFORMANCE_FULL=1) runs every arch through both modes.
CHAOS_QUICK = (("llama3-8b", "plain"), ("llama3-8b", "frontend"))

CHAOS_CELLS = (tuple((a, m) for a in ARCH_IDS for m in CHAOS_MODES)
               if FULL else CHAOS_QUICK)


@pytest.mark.parametrize(
    "arch,mode", CHAOS_CELLS,
    ids=[f"chaos-{a}-{m}" for a, m in CHAOS_CELLS])
def test_chaos_cell(arch, mode):
    """Fault-injected churn: run_chaos already raised on any byte
    divergence, unaccounted request loss, failed recovery, or a
    terminal plane that never re-specialized — the report proves the
    run injected every fault type and recovered from each."""
    report = run_chaos(arch, mode, seed=0, n_events=70)
    assert set(report["faults"]) == set(FAULT_KINDS)
    assert report["recovery_arcs"] >= len(FAULT_KINDS)
    assert report["final_state"] == "healthy"
    assert report["compares"] >= 10
    if mode == "plain":
        # at least one faulted step was retried byte-identically
        # through the degraded generic path
        assert report["retried_steps"] >= 1
    else:
        # the degraded plane rejected explicitly, never silently
        assert report["rejected_degraded"] >= 1
    specialized = [(t, i) for t, i in report["impls_seen"]
                   if i != "gather"]
    assert specialized, report["impls_seen"]


def test_chaos_moves_are_fenced_out_of_plain_schedules():
    """Chaos moves must not perturb the long-standing plain schedules
    (cross-process determinism rests on them); with chaos=True every
    fault kind fires as a contiguous fault->steps->recovery episode."""
    plane = build_plane("llama3-8b")
    plain_kinds = {e.kind for e in generate_schedule(plane, seed=3)}
    assert "chaos_fault" not in plain_kinds
    assert "schedule_recovery" not in plain_kinds

    s1 = generate_schedule(plane, seed=3, chaos=True)
    s2 = generate_schedule(plane, seed=3, chaos=True)
    assert [e.kind for e in s1] == [e.kind for e in s2]
    kinds = [e.kind for e in s1]
    faults = [e.payload["fault"] for e in s1 if e.kind == "chaos_fault"]
    assert set(faults) >= set(FAULT_KINDS)
    assert kinds.count("schedule_recovery") == kinds.count("chaos_fault")
    # each episode is contiguous: only steps between a fault and its
    # recovery, so every fault's full arc is exercised before any other
    # control churn lands
    for i, k in enumerate(kinds):
        if k == "chaos_fault":
            j = i + 1
            while kinds[j] == "step":
                j += 1
            assert kinds[j] == "schedule_recovery", (i, kinds[i:j + 1])


# ---------------------------------------------------------------------------
# schedule generation: determinism + guarantees
# ---------------------------------------------------------------------------

def _payload_leaves(ev):
    out = []

    def walk(x):
        if isinstance(x, dict):
            for k in sorted(x):
                out.append(k)
                walk(x[k])
        elif isinstance(x, (list, tuple)):
            for e in x:
                walk(e)
        else:
            out.append(x)
    walk(ev.payload)
    return out


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "pixtral-12b"])
def test_schedule_is_deterministic_and_complete(arch):
    """Same (plane, seed) => byte-identical event stream (the property
    cross-process plan determinism rests on), every applicable
    registered move fires, and both mispredicts are step-followed."""
    plane = build_plane(arch)
    s1 = generate_schedule(plane, seed=7)
    s2 = generate_schedule(plane, seed=7)
    assert [e.kind for e in s1] == [e.kind for e in s2]
    for a, b in zip(s1, s2):
        for x, y in zip(_payload_leaves(a), _payload_leaves(b)):
            assert np.array_equal(x, y)

    kinds = [e.kind for e in s1]
    assert kinds.count("inject_mispredict") == 2
    for i, k in enumerate(kinds):
        if k == "inject_mispredict":     # deopt must be observable
            assert kinds[i + 1] == "step"
    assert kinds[-5:] == ["recompile"] + ["step"] * 4
    updated = {e.payload["table"] for e in s1
               if e.kind == "control_update"}
    if plane.has_ssm:
        assert "ssm_state" in updated    # flush/warm moves fired
    if plane.has_media:
        assert "media_patches" in updated
    assert "flag_flip" in kinds and "hotset_rotate" in kinds


def test_register_churn_move_reaches_generated_schedules():
    """The extension seam a new specialization pass uses: registering a
    move makes it fire at least once in every schedule for planes it
    applies to, and never for planes it does not."""
    seen = []

    def factory(plane, rng, traffic):
        seen.append(plane.arch_id)
        return ChurnEvent("sampler_rearm", {})

    register_churn_move("_test_move", factory,
                        applies=lambda p: p.has_moe)
    try:
        moe, dense = build_plane("deepseek-v2-236b"), \
            build_plane("llama3-8b")
        assert "_test_move" in churn_moves(moe)
        assert "_test_move" not in churn_moves(dense)
        generate_schedule(moe, seed=11)
        assert seen and set(seen) == {"deepseek-v2-236b"}
        n = len(seen)                            # >= once, maybe more
        generate_schedule(dense, seed=11)
        assert len(seen) == n                    # gated off for dense
    finally:
        _MOVES.pop("_test_move", None)


# ---------------------------------------------------------------------------
# cross-process plan-signature determinism
# ---------------------------------------------------------------------------

def test_plan_fingerprints_match_across_processes():
    """Two independent processes fed the identical warmup scenario must
    plan byte-identical signatures.  The child runs under a different
    PYTHONHASHSEED, so any Python-hash / set-order / id() leakage into
    planning shows up as a fingerprint mismatch."""
    arch = "llama3-8b"
    here = run_fingerprints([arch], seed=0)
    env = dict(os.environ)
    env["PYTHONHASHSEED"] = "271828"
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = str(pathlib.Path(repro.__file__).resolve().parents[1])
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.fingerprint", arch],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout) == here
