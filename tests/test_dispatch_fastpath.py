"""The steady-state dispatch fast path: seqlock'd dispatch, fused
multi-step windows, and the batch-placement fast path.

Covers the PR's acceptance criteria: the executable runs OUTSIDE the
runtime lock and writers quiesce on the in-flight step; `step_many`'s
fused K-step windows are byte-identical to K=1 stepping (generic and
specialized), cached with K in the executable-cache key, and hoisting
the program guard / sampling decision to window granularity preserves
§4.4 semantics — a control update landing mid-window is queued, the
*next* window runs generic, and replayed updates land in FIFO order;
`place_batch`/`_place_batch` never re-transfer an already-resident
batch; steady-state dispatch coalesces its stats into one locked call
per step (or per window); `PlaneSampling` learns the window-granular
cadence.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, MorpheusRuntime, PlaneSampling, \
    SketchConfig, Table, TableSet, stack_batches
from repro.core import runtime as runtime_mod

N_VALID = 48


def _user_step(params, ctx, batch):
    row = ctx.lookup("classes", batch["cls"], fields=("scale",))
    x = batch["x"] * row["scale"][:, None]
    old = ctx.lookup("sess", batch["slot"], fields=("count",))
    ctx.update("sess", batch["slot"], {"count": old["count"] + 1})
    return x


def _tables(seed=0):
    return TableSet([
        Table("classes",
              {"scale": np.linspace(1.0, 2.0, N_VALID).astype(np.float32)
               + seed},
              n_valid=N_VALID, instrument=True),
        Table("sess", {"count": np.zeros(16, np.int32)}, n_valid=16,
              mutability="rw"),
    ])


def _batch(i=0):
    rng = np.random.default_rng(i)
    cls = np.arange(16) % N_VALID
    cls[:12] = np.arange(12) % 3          # skewed hot classes {0,1,2}
    return {"cls": jnp.asarray(cls, jnp.int32),
            "x": jnp.asarray(rng.standard_normal((16, 4)), jnp.float32),
            "slot": jnp.asarray(rng.integers(0, 16, 16), jnp.int32)}


def _mk(seed=0, sample_every=2, **kw):
    cfg = EngineConfig(sketch=SketchConfig(sample_every=sample_every,
                                           max_hot=4, hot_coverage=0.5),
                       **kw)
    return MorpheusRuntime(_user_step, _tables(seed), None, _batch(),
                           cfg=cfg)


# ---------------------------------------------------------------------------
# fused multi-step execution
# ---------------------------------------------------------------------------

def test_step_many_byte_identical_to_single_steps():
    """One lax.scan-fused K-step window == K single steps, bit for bit —
    outputs AND the threaded state (RW table writes, guards)."""
    rt1, rt2 = _mk(), _mk()
    try:
        batches = [_batch(i) for i in range(8)]
        singles = [np.asarray(rt1.step(b)) for b in batches]
        fused = np.asarray(rt2.step_many(batches))
        assert fused.shape[0] == 8
        for i in range(8):
            np.testing.assert_array_equal(singles[i], fused[i])
        np.testing.assert_array_equal(
            np.asarray(rt1.state.tables["sess"]["count"]),
            np.asarray(rt2.state.tables["sess"]["count"]))
        # specialized windows too
        rt1.recompile(block=True)
        rt2.recompile(block=True)
        assert rt2.plan.label.startswith("specialized")
        batches = [_batch(100 + i) for i in range(4)]
        singles = [np.asarray(rt1.step(b)) for b in batches]
        fused = np.asarray(rt2.step_many(batches))
        for i in range(4):
            np.testing.assert_array_equal(singles[i], fused[i])
    finally:
        rt1.close()
        rt2.close()


def test_step_many_cached_with_k_in_the_key():
    """Fused executables live in the ExecutableCache with K in the key:
    the second window of the same K compiles nothing, a different K
    compiles its own executable, and K never aliases the single-step
    entry."""
    rt = _mk()

    def join_warms():
        # the first window of each (structure, K) kicks off a background
        # warm of the fused generic deopt target — join it so compile
        # counts below are deterministic
        for t in rt._warm_threads:
            t.join(timeout=120)

    try:
        rt.sampler.pin(1)                 # every window instruments
        batches = [_batch(i) for i in range(4)]
        rt.step_many(batches)
        join_warms()
        c0 = rt.engine.compile_count
        rt.step_many([_batch(10 + i) for i in range(4)])
        assert rt.engine.compile_count == c0          # K=4 cached
        rt.step_many([_batch(20 + i) for i in range(2)])
        join_warms()
        # K=2 is a new executable (+ its background generic warm)
        assert rt.engine.compile_count == c0 + 2
        # with the sampler pinned at 1 every window samples -> the
        # instrumented twin is the fused role plan that ran and cached
        twin = rt._instr_twin(rt.plan, rt._active_isites)
        k4 = rt._exec_key(twin, stack_batches(batches), True,
                          rt._active_isites, fuse=4)
        k1 = rt._exec_key(twin, batches[0], True, rt._active_isites)
        assert k4 != k1
        assert rt.exec_cache.peek(k4) is not None
    finally:
        rt.close()


def test_step_many_rejects_ambiguous_prestacked_input():
    """A plain per-step batch is shape-indistinguishable from a stacked
    window: without an explicit k the call must fail loudly instead of
    silently scanning over the batch dimension."""
    rt = _mk()
    try:
        with pytest.raises(TypeError):
            rt.step_many(_batch())                   # no k: ambiguous
        with pytest.raises(ValueError):
            rt.step_many([_batch(0), _batch(1)], k=3)   # k mismatch
        with pytest.raises(ValueError):
            rt.step_many(stack_batches([_batch(i) for i in range(4)]),
                         k=8)                        # wrong leading axis
    finally:
        rt.close()


def test_step_many_k1_degrades_to_single_step():
    rt = _mk()
    try:
        out = rt.step_many([_batch(3)])
        ref = _mk().step(_batch(3))
        np.testing.assert_array_equal(np.asarray(out)[0], np.asarray(ref))
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# §4.4 semantics at window granularity
# ---------------------------------------------------------------------------

def test_midwindow_update_queues_then_next_window_deopts_in_order():
    """A control_update landing mid-`step_many` window does NOT block:
    it queues, drains (FIFO) at the window's commit, and the *next*
    window runs generic via the program guard — byte-identical to the
    same schedule under K=1 stepping."""
    rt = _mk()
    ref = _mk()
    try:
        w0 = [_batch(i) for i in range(4)]
        w1 = [_batch(10 + i) for i in range(4)]
        rt.step_many(w0)
        rt.recompile(block=True)
        for b in w0:
            ref.step(b)
        ref.recompile(block=True)

        # block the fused executable mid-window so the updates land
        # while the window is provably in flight
        started, release = threading.Event(), threading.Event()
        real = rt._fused_exec

        def gated(*a, **kw):
            exe, mkey = real(*a, **kw)

            def wrapper(params, state, batch):
                started.set()
                assert release.wait(timeout=30)
                return exe(params, state, batch)
            return wrapper, mkey

        rt._fused_exec = gated
        out = {}
        th = threading.Thread(
            target=lambda: out.update(w=rt.step_many(w1)))
        th.start()
        assert started.wait(timeout=30)
        sA = np.full(N_VALID, 5.0, np.float32)
        sB = np.full(N_VALID, 7.0, np.float32)
        rt.control_update("classes", {"scale": sA})   # queued: in flight
        rt.control_update("classes", {"scale": sB})   # queued behind A
        assert len(rt._queued) == 2                   # did not block
        v_before = rt.tables.version
        release.set()
        th.join(timeout=60)
        assert not th.is_alive()
        rt._fused_exec = real

        # the drain applied both updates, in order: B is live
        assert rt.tables.version > v_before
        np.testing.assert_array_equal(
            np.asarray(rt.state.tables["classes"]["scale"]), sB)
        # the window itself ran pre-update code
        for b, o in zip(w1, np.asarray(out["w"])):
            np.testing.assert_array_equal(np.asarray(ref.step(b)), o)
        # the NEXT window deopts (program guard) and serves B's contents
        ref.control_update("classes", {"scale": sA})
        ref.control_update("classes", {"scale": sB})
        w2 = [_batch(20 + i) for i in range(4)]
        d0 = rt.stats.deopt_steps
        fused = np.asarray(rt.step_many(w2))
        assert rt.stats.deopt_steps == d0 + 4
        for b, o in zip(w2, fused):
            np.testing.assert_array_equal(np.asarray(ref.step(b)), o)
    finally:
        rt.close()
        ref.close()


def test_fused_generic_deopt_target_is_precompiled():
    """The §4.4 guarantee at window granularity: the fused generic
    deopt target is warmed in the background when a window structure is
    first seen, so a guard-tripped window swaps to generic with ZERO
    inline compiles."""
    rt = _mk()
    try:
        w = [_batch(i) for i in range(4)]
        rt.step_many(w)
        for t in rt._warm_threads:
            t.join(timeout=120)
        c0 = rt.engine.compile_count
        rt.control_update("classes",
                          {"scale": np.full(N_VALID, 2.5, np.float32)})
        d0 = rt.stats.deopt_steps
        rt.step_many(w)                          # guard trips
        assert rt.stats.deopt_steps == d0 + 4
        assert rt.engine.compile_count == c0     # no inline t2
    finally:
        rt.close()


def test_update_queued_during_single_step_drains_at_commit():
    """The same queue/drain protocol covers plain step(): the control
    plane never blocks behind an in-flight executable."""
    rt = _mk()
    try:
        rt.step(_batch())
        started, release = threading.Event(), threading.Event()
        spec = rt._active

        def gated(params, state, batch):
            started.set()
            assert release.wait(timeout=30)
            return spec[1](params, state, batch)

        with rt._cond:
            rt._active = (spec[0], gated, gated, gated)
        th = threading.Thread(target=lambda: rt.step(_batch(1)))
        th.start()
        assert started.wait(timeout=30)
        rt.control_update("classes",
                          {"scale": np.full(N_VALID, 9.0, np.float32)})
        assert rt._queued                               # non-blocking
        release.set()
        th.join(timeout=60)
        assert not th.is_alive()
        assert not rt._queued                           # drained
        assert float(rt.state.tables["classes"]["scale"][0]) == 9.0
        with rt._cond:
            rt._active = spec
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# the seqlock protocol
# ---------------------------------------------------------------------------

def test_executable_runs_outside_the_runtime_lock():
    """The tentpole property: during device execution the runtime lock
    is FREE (the seed held it across the whole step)."""
    rt = _mk()
    try:
        seen = {}
        spec = rt._active

        def probe(params, state, batch):
            seen["locked"] = rt._lock.locked()
            seen["stepping"] = rt._stepping
            return spec[1](params, state, batch)

        with rt._cond:
            rt._active = (spec[0], probe, probe, probe)
        rt.step(_batch())
        with rt._cond:
            rt._active = spec
        assert seen["locked"] is False
        assert seen["stepping"] is True
    finally:
        rt.close()


def test_writer_quiesces_and_bumps_generation():
    """A writer (recompile swap / control update) waits for the
    in-flight step, then bumps the generation so prepared dispatch work
    revalidates."""
    rt = _mk()
    try:
        g0 = rt._gen
        rt.control_update("classes",
                          {"scale": np.full(N_VALID, 3.0, np.float32)})
        assert rt._gen > g0                      # writer bumped
        g1 = rt._gen
        rt.recompile(block=True)                 # swap is a writer too
        assert rt._gen > g1
        # claim validation: a stale generation is refused
        assert rt._begin_step(expect_gen=g0) is None
        claim = rt._begin_step(expect_gen=rt._gen)
        assert claim is not None
        rt._abort_step()
    finally:
        rt.close()


def test_concurrent_steps_and_control_churn_stay_consistent():
    """Stress the seqlock: steppers, a control-update writer and
    blocking recompiles race; every step commits, nothing deadlocks,
    and the final state matches the last update."""
    rt = _mk()
    errors = []
    N = 40

    def stepper():
        try:
            for i in range(N):
                rt.step(_batch(i))
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    def churner():
        try:
            for i in range(10):
                rt.control_update(
                    "classes",
                    {"scale": np.full(N_VALID, float(i), np.float32)})
                rt.recompile(block=True)
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    try:
        threads = [threading.Thread(target=stepper) for _ in range(2)]
        threads.append(threading.Thread(target=churner))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
            assert not t.is_alive(), "deadlocked"
        assert not errors, errors
        assert rt.stats.steps == 2 * N
        # queued updates all landed (none stranded)
        assert not rt._queued
        assert float(rt.state.tables["classes"]["scale"][0]) == 9.0
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# batch placement fast path
# ---------------------------------------------------------------------------

def _mesh1():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def test_second_step_on_placed_batch_performs_zero_transfers():
    """The placement satellite: arrays whose committed sharding already
    matches pass through — stepping the same batch object twice
    transfers it once."""
    cfg = EngineConfig(sketch=SketchConfig(sample_every=2, max_hot=4,
                                           hot_coverage=0.5),
                       mesh=_mesh1())
    rt = MorpheusRuntime(_user_step, _tables(), None, _batch(), cfg=cfg)
    calls = []
    real = runtime_mod._device_put
    try:
        runtime_mod._device_put = \
            lambda *a, **kw: (calls.append(1), real(*a, **kw))[1]
        host = {k: np.asarray(v) for k, v in _batch().items()}
        placed = rt.place_batch(host)                # host arrays: H2D
        assert len(calls) == 1                       # first placement
        jax.block_until_ready(rt.step(placed))
        assert len(calls) == 1                       # step re-used it
        jax.block_until_ready(rt.step(placed))
        assert len(calls) == 1                       # zero transfers
        assert rt.place_batch(placed) is placed      # prefetch no-op
        assert rt.stats.batch_transfers == 1
        # fused layout is place-once too
        w = rt.place_batch([_batch(i) for i in range(4)], fused=True)
        n = len(calls)
        jax.block_until_ready(rt.step_many(w, k=4))
        jax.block_until_ready(rt.step_many(w, k=4))
        assert len(calls) == n
    finally:
        runtime_mod._device_put = real
        rt.close()


# ---------------------------------------------------------------------------
# coalesced stats + window-granular sampling cadence
# ---------------------------------------------------------------------------

def test_steady_step_makes_one_locked_stats_call():
    rt = _mk()
    try:
        b = _batch()
        rt.step(b)
        lc0, s0 = rt.stats.locked_calls, rt.stats.steps
        for _ in range(6):
            rt.step(b)
        assert rt.stats.locked_calls - lc0 <= rt.stats.steps - s0
        rt.sampler.pin(1)                            # every window samples
        w = [_batch(i) for i in range(4)]
        rt.step_many(w)                              # compile path (twin)
        lc0 = rt.stats.locked_calls
        for _ in range(3):
            rt.step_many(w)
        assert rt.stats.locked_calls - lc0 <= 3      # one per WINDOW
    finally:
        rt.close()


def test_sampling_learns_window_granular_cadence():
    sampler = PlaneSampling(SketchConfig(sample_every=8))
    sampler.pin(4)
    # one sampled window per sample_every WINDOWS, for any K: a sampled
    # window instruments all K steps, so this is what preserves the
    # per-step duty cycle (K / (4*K) = 1/4) and the sketch data rate
    for k in (2, 4, 32):
        assert sampler.window_every(k) == 4
    hits = [sampler.should_sample_window(w, 8) for w in range(1, 9)]
    assert hits == [False, False, False, True] * 2
    duty = sum(8 for w in range(1, 33)
               if sampler.should_sample_window(w, 8)) / (32 * 8)
    assert duty == 1.0 / 4
    # disarmed: never
    sampler.disarm_after = 1
    sampler.armed = False
    assert not sampler.should_sample_window(4, 4)


def test_fused_window_instruments_and_publishes_once():
    """A sampled fused window records all K steps' traffic into the
    sketches and publishes the back buffer once per window."""
    rt = _mk(sample_every=2)
    try:
        rt.sampler.pin(1)                            # sample every window
        seq0 = rt._backbuf.seq
        i0 = rt.stats.instr_steps
        rt.step_many([_batch(i) for i in range(4)])  # window 1: sampled
        assert rt.stats.instr_steps == i0 + 4
        assert rt._backbuf.seq == seq0 + 1           # ONE publish
        snap = rt._host_instr_snapshot()
        assert int(snap["classes#0"]["total"]) > 0
    finally:
        rt.close()
