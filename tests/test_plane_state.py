"""The redesigned core API: PlaneState pytree, donated compile,
flag-keying contract, and the pluggable pass registry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DataPlaneCtx, EngineConfig, MorpheusEngine, \
    PassRegistry, PlaneState, SiteSpec, SketchConfig, SpecializationPass, \
    default_registry
from repro.core.tables import CallSite
from repro.serving import ServeConfig, build_params, build_tables, \
    make_synthetic_batch, make_serve_step

KEY = jax.random.PRNGKey(0)
SK = SketchConfig(sample_every=2, max_hot=4, hot_coverage=0.5)


@pytest.fixture(scope="module")
def engine():
    cfg = ServeConfig()
    params = build_params(cfg, KEY)
    tables = build_tables(cfg, KEY)
    eng = MorpheusEngine(
        make_serve_step(cfg), tables,
        EngineConfig(sketch=SK,
                     features={"vision_enabled": False,
                               "track_sessions": True},
                     moe_router_table="router"))
    batch = make_synthetic_batch(cfg, KEY)
    eng.analyze(params, batch)
    return cfg, eng, params, batch


# ---------------------------------------------------------------------------
# PlaneState pytree
# ---------------------------------------------------------------------------

def test_plane_state_tree_roundtrip(engine):
    _, eng, _, _ = engine
    state = eng.init_state()
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert len(leaves) > 0
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, PlaneState)
    assert set(rebuilt.tables) == set(state.tables)
    assert set(rebuilt.instr) == set(state.instr)
    assert set(rebuilt.guards) == set(state.guards)
    for a, b in zip(leaves, jax.tree_util.tree_leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plane_state_tree_map_and_replace(engine):
    _, eng, _, _ = engine
    state = eng.init_state()
    doubled = jax.tree.map(lambda x: x * 2, state)
    assert isinstance(doubled, PlaneState)
    np.testing.assert_array_equal(
        np.asarray(doubled.tables["req_class"]["temperature"]),
        2 * np.asarray(state.tables["req_class"]["temperature"]))
    swapped = state.replace(guards={})
    assert swapped.guards == {} and swapped.tables is state.tables


def test_donation_does_not_change_results(engine):
    cfg, eng, params, batch = engine
    plan = eng.generic_plan()
    exe_d, _ = eng.compile(plan, params, eng.init_state(), batch,
                           donate=True)
    exe_p, _ = eng.compile(plan, params, eng.init_state(), batch,
                           donate=False)
    out_d, st_d = exe_d(params, eng.init_state(), batch)
    out_p, st_p = exe_p(params, eng.init_state(), batch)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_p),
                               rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(st_d),
                    jax.tree_util.tree_leaves(st_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compile_accepts_per_leaf_shardings(engine):
    cfg, eng, params, batch = engine
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    rep = jax.sharding.NamedSharding(mesh,
                                     jax.sharding.PartitionSpec())
    exe, _ = eng.compile(eng.generic_plan(), params, eng.init_state(),
                         batch, in_shardings=rep, out_shardings=rep)
    out, st = exe(params, eng.init_state(), batch)
    assert isinstance(st, PlaneState)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# flag keying contract
# ---------------------------------------------------------------------------

def test_ctx_flag_and_plan_flags_agree_on_keying(engine):
    """Regression: plan flags are keyed by flag NAME (what ctx.flag looks
    up), never by the flag call site's id."""
    _, eng, _, _ = engine
    plan, _, _ = eng.build_plan({})
    assert plan.flags["vision_enabled"] is False
    assert plan.flags["track_sessions"] is True
    flag_sites = [s.site_id for s in eng.sites if s.kind == "flag"]
    assert flag_sites, "serve step registers flag sites"
    assert not any(sid in plan.flags for sid in flag_sites)

    ctx = DataPlaneCtx(plan, eng.init_state(), eng.cfg.sketch)
    assert ctx.flag("vision_enabled", default=True) is False
    assert ctx.flag("unplanned_flag", default=True) is True


# ---------------------------------------------------------------------------
# pass registry
# ---------------------------------------------------------------------------

def test_default_registry_order_and_lookup():
    reg = default_registry("router")
    names = reg.names()
    assert names.index("eliminated") < names.index("inlined") \
        < names.index("const_row") < names.index("moe_fastpath") \
        < names.index("fastpath") < names.index("onehot")
    assert names[-1] == "guard_elision"
    assert reg.get("moe_fastpath").router_table == "router"


def test_registry_register_before_after_remove():
    reg = default_registry(None)
    class NopPass(SpecializationPass):
        name = "nop"
    reg.register(NopPass(), before="fastpath")
    names = reg.names()
    assert names.index("nop") == names.index("fastpath") - 1
    reg.remove("nop")
    assert "nop" not in reg.names()
    reg.register(NopPass(), after="eliminated")
    assert reg.names().index("nop") == reg.names().index("eliminated") + 1
    with pytest.raises(ValueError):
        reg.register(NopPass())          # duplicate name

    class OtherPass(SpecializationPass):
        name = "other"
    before = reg.names()
    with pytest.raises(KeyError):
        reg.register(OtherPass(), before="does_not_exist")
    # failed register must leave the pipeline unchanged
    assert reg.names() == before


def test_custom_pass_claims_site_first(engine):
    """A user-registered pass ahead of the pipeline overrides the
    engine's decision for the sites it matches."""
    cfg_s = ServeConfig()
    params = build_params(cfg_s, KEY)
    tables = build_tables(cfg_s, KEY)

    class PinGather(SpecializationPass):
        name = "pin_gather"
        def match(self, site):
            return site.kind == "lookup" and site.table == "req_class"
        def plan(self, site, snapshot, stats):
            return SiteSpec(impl="gather")

    reg = default_registry("router")
    reg.register(PinGather(), before="eliminated")
    eng = MorpheusEngine(
        make_serve_step(cfg_s), tables,
        EngineConfig(sketch=SK, passes=reg, moe_router_table="router"))
    batch = make_synthetic_batch(cfg_s, KEY)
    eng.analyze(params, batch)
    plan, _, stats = eng.build_plan({})
    assert stats["pin_gather"] >= 1
    impls = {sid.split("#")[0]: s.impl for sid, s in plan.sites}
    assert impls["req_class"] == "gather"     # not const_row/inline


def test_moe_pass_emits_site_spec_not_flag(engine):
    """The MoE hot path is a registered pass producing a moe_fastpath
    SiteSpec on the router site — no __moe_hot__ side-channel."""
    cfg_s = ServeConfig()
    params = build_params(cfg_s, KEY)
    for lp in params["layers"]:
        bias = np.zeros(cfg_s.n_experts, np.float32)
        bias[:3] = 6.0
        lp["moe"]["b_router"] = jnp.asarray(bias)
    from repro.core import MorpheusRuntime
    rt = MorpheusRuntime(
        make_serve_step(cfg_s), build_tables(cfg_s, KEY), params,
        make_synthetic_batch(cfg_s, KEY),
        cfg=EngineConfig(sketch=SK,
                         features={"vision_enabled": False,
                                   "track_sessions": True},
                         moe_router_table="router"))
    for i in range(8):
        rt.step(make_synthetic_batch(cfg_s, jax.random.PRNGKey(i), 8,
                                   "high"))
    rt.recompile(block=True)
    hot = rt.hot_experts()
    assert hot is not None and len(hot) >= 1
    assert rt.plan.hot_experts("router") == hot
    assert "__moe_hot__" not in (rt.plan.flags or {})
    impls = {sid: s.impl for sid, s in rt.plan.sites}
    assert any(sid.startswith("router#") and impl == "moe_fastpath"
               for sid, impl in impls.items())
