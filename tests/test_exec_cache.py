"""Executable cache, revalidation fast path, and churn-path dispatch.

The PR-3 contract: plan *identity* (version) lives only in the host-side
program guard; executable *identity* is the plan signature.  A recompile
cycle whose planned signature is unchanged performs ZERO jax traces and
ZERO XLA compiles (revalidation); a cycle whose signature is cached
swaps without compiling; oscillating churn (A -> B -> A) compiles each
distinct signature exactly once.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ExecutableCache, MorpheusRuntime, \
    SketchConfig, SpecializationPlan, Table, TableSet
from repro.core.execcache import batch_key

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ExecutableCache unit
# ---------------------------------------------------------------------------

def test_cache_lru_eviction_and_stats():
    c = ExecutableCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # a is now most recent
    c.put("c", 3)                   # evicts b (LRU)
    assert c.peek("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.get("b") is None
    assert c.stats.evictions == 1
    assert c.stats.hits == 3 and c.stats.misses == 1
    assert len(c) == 2


def test_get_or_compile_deduplicates_inflight_compiles():
    """The multi-plane stampede guard: concurrent get_or_compile calls
    for one key run compile_fn exactly once — the second caller waits
    for the owner's insert instead of compiling again."""
    c = ExecutableCache(capacity=8)
    started, gate = threading.Event(), threading.Event()
    compiles = []

    def slow():
        started.set()
        assert gate.wait(timeout=10)
        compiles.append(1)
        return "exe", 1.23

    out = []
    t1 = threading.Thread(
        target=lambda: out.append(c.get_or_compile("k", slow)))
    t1.start()
    assert started.wait(timeout=10)          # owner is inside compile_fn
    t2 = threading.Thread(
        target=lambda: out.append(c.get_or_compile("k", slow)))
    t2.start()
    time.sleep(0.05)                         # t2 parks as a waiter
    gate.set()
    t1.join(10)
    t2.join(10)
    assert len(compiles) == 1
    by_aux = sorted(out, key=lambda p: p[1] is None)
    assert by_aux[0] == ("exe", 1.23)        # the owner paid (got aux)
    assert by_aux[1] == ("exe", None)        # the waiter shared it
    assert c.stats.inflight_waits == 1
    assert c.stats.inserts == 1


def test_get_or_compile_owner_failure_unwedges_waiters():
    c = ExecutableCache(capacity=8)
    started = threading.Event()

    def bad():
        started.set()
        time.sleep(0.05)
        raise RuntimeError("t2 died")

    res = {}

    def owner():
        try:
            c.get_or_compile("k", bad)
        except RuntimeError as e:
            res["owner"] = e

    t = threading.Thread(target=owner)
    t.start()
    assert started.wait(timeout=10)
    # the waiter must claim ownership after the failure and compile
    res["waiter"] = c.get_or_compile("k", lambda: ("exe", 0.5))
    t.join(10)
    assert isinstance(res["owner"], RuntimeError)
    assert res["waiter"] == ("exe", 0.5)
    assert c.get("k") == "exe"


def test_eviction_racing_inflight_waiter_recompiles():
    """Eviction racing an in-flight waiter: the owner's insert can be
    evicted (capacity pressure from another plane) BEFORE a parked
    waiter re-checks the map.  The waiter must not return None or wedge
    — it re-loops, finds the key missing, claims ownership and compiles
    again.  Deterministic schedule: a cache subclass whose ``put``
    immediately inserts a filler key into a capacity-1 cache, so the
    owner's entry is always gone by the time the waiter wakes."""
    class EvictingCache(ExecutableCache):
        filler_puts = 0

        def put(self, key, exe):
            super().put(key, exe)
            if key == "k" and not self.filler_puts:
                self.filler_puts += 1
                super().put("filler", "other")   # capacity 1: evicts "k"

    c = EvictingCache(capacity=1)
    started, gate = threading.Event(), threading.Event()
    compiles = []

    def compile_fn():
        compiles.append(1)
        started.set()
        assert gate.wait(timeout=10)
        return f"exe{len(compiles)}", 0.1

    out = []
    t1 = threading.Thread(
        target=lambda: out.append(c.get_or_compile("k", compile_fn)))
    t1.start()
    assert started.wait(timeout=10)          # owner inside compile_fn
    t2 = threading.Thread(
        target=lambda: out.append(c.get_or_compile("k", compile_fn)))
    t2.start()
    deadline = time.time() + 10
    while c.stats.inflight_waits < 1 and time.time() < deadline:
        time.sleep(0.005)
    assert c.stats.inflight_waits == 1       # t2 is parked as a waiter
    gate.set()          # owner inserts; filler evicts it; waiter wakes
    t1.join(10)
    t2.join(10)
    assert len(compiles) == 2                # waiter re-owned the key
    assert sorted(p[0] for p in out) == ["exe1", "exe2"]
    assert all(p[1] == 0.1 for p in out)     # both were owners (got aux)
    assert c.peek("k") == "exe2"             # final entry is valid
    assert c.stats.evictions >= 2
    assert not c._inflight                   # no wedged ownership


# ---------------------------------------------------------------------------
# plan identity: signature vs key
# ---------------------------------------------------------------------------

def test_signature_excludes_version_key_includes_it():
    p = SpecializationPlan(version=3, sites=(), flags={"f": True})
    q = SpecializationPlan(version=9, sites=(), flags={"f": True})
    assert p.signature == q.signature
    assert p.key != q.key
    assert p.key == (3,) + p.signature


def test_site_lookup_is_dict_backed():
    from repro.core import SiteSpec
    sites = tuple((f"t#{i}", SiteSpec(impl="onehot")) for i in range(50))
    p = SpecializationPlan(sites=sites)
    assert p.site("t#17") is sites[17][1]
    assert p.site("missing") is None
    # survives dataclasses.replace (post_init rebuilds the map)
    import dataclasses
    r = dataclasses.replace(p, version=5)
    assert r.site("t#3") is sites[3][1]


# ---------------------------------------------------------------------------
# runtime churn path
# ---------------------------------------------------------------------------

def _user_step(params, ctx, batch):
    row = ctx.lookup("classes", batch["cls"], fields=("scale",))
    x = batch["x"] * row["scale"][:, None]
    if ctx.flag("boost", default=False):
        x = x + 1.0
    return x


def _scales(n, seed=0):
    return np.linspace(1.0, 2.0, n).astype(np.float32) + seed


def _mk_runtime(n_valid=8, instrument=False, capacity=64, cache=None,
                signature_cache=True, features=None):
    tables = TableSet([Table(
        "classes", {"scale": _scales(n_valid)}, n_valid=n_valid,
        instrument=instrument)])
    batch = {"cls": jnp.arange(8, dtype=jnp.int32) % min(n_valid, 8),
             "x": jnp.ones((8, 4), jnp.float32)}
    cfg = EngineConfig(
        sketch=SketchConfig(sample_every=2, max_hot=4, hot_coverage=0.5),
        features=dict(features or {}),
        exec_cache_capacity=capacity,
        signature_cache=signature_cache)
    rt = MorpheusRuntime(_user_step, tables, None, batch, cfg=cfg,
                         exec_cache=cache)
    rt._batch = batch
    return rt


def _expected(rt, batch, boost=False):
    scale = np.asarray(rt.tables["classes"].fields["scale"])
    out = np.asarray(batch["x"]) * scale[np.asarray(batch["cls"])][:, None]
    return out + 1.0 if boost else out


def test_revalidation_zero_trace_zero_compile():
    """The acceptance criterion: a recompile cycle whose plan signature
    is unchanged performs zero jax traces and zero XLA compiles."""
    rt = _mk_runtime()
    try:
        rt.recompile(block=True)                 # specialized active
        assert rt.stats.swaps == 1
        eng = rt.engine
        e0, l0, c0 = rt.exec, eng.lower_count, eng.compile_count
        rt.tables.bump_version("config-push")    # pure control churn
        assert rt.tables.version != rt.plan.version
        info = rt.recompile(block=True)
        assert info["revalidated"] is True
        assert rt.stats.revalidations == 1
        assert (eng.lower_count, eng.compile_count) == (l0, c0)
        assert rt.stats.swaps == 1               # no swap either
        assert rt.exec is e0                     # same executable object
        assert rt.plan.version == rt.tables.version   # restamped
        d0 = rt.stats.deopt_steps
        out = rt.step(rt._batch)                 # guard must NOT trip
        assert rt.stats.deopt_steps == d0
        np.testing.assert_allclose(np.asarray(out),
                                   _expected(rt, rt._batch), rtol=1e-6)
    finally:
        rt.close()


def test_oscillation_a_b_a_compiles_at_most_twice():
    """A -> B -> A control oscillation: two distinct signatures, two XLA
    compiles total — the third cycle swaps to the cached A executable."""
    rt = _mk_runtime()       # no instrumented sites => twins share code
    try:
        eng = rt.engine
        base = eng.compile_count
        for i, boost in enumerate((True, False, True)):
            rt.set_feature("boost", boost)
            info = rt.recompile(block=True)
            assert info["revalidated"] is False
            out = rt.step(rt._batch)
            np.testing.assert_allclose(
                np.asarray(out), _expected(rt, rt._batch, boost=boost),
                rtol=1e-6)
            if i == 1:
                after_b = eng.compile_count
        assert eng.compile_count - base <= 2
        assert eng.compile_count == after_b      # cycle 3: zero compiles
        assert rt.stats.swaps == 3               # but it DID swap
    finally:
        rt.close()


def test_lru_eviction_recompiles_correctly():
    rt = _mk_runtime(capacity=2)
    try:
        eng = rt.engine
        for seed in (1, 2, 3):                   # distinct inline values
            rt.control_update("classes", {"scale": _scales(8, seed)})
            rt.recompile(block=True)
            out = rt.step(rt._batch)
            np.testing.assert_allclose(np.asarray(out),
                                       _expected(rt, rt._batch),
                                       rtol=1e-6)
        assert rt.exec_cache.stats.evictions > 0
        # back to an evicted signature: must recompile, not crash
        c0 = eng.compile_count
        rt.control_update("classes", {"scale": _scales(8, 1)})
        rt.recompile(block=True)
        assert eng.compile_count > c0
        out = rt.step(rt._batch)
        np.testing.assert_allclose(np.asarray(out),
                                   _expected(rt, rt._batch), rtol=1e-6)
    finally:
        rt.close()


def test_cached_executable_still_deopts_after_racing_update():
    """A swap served from the cache must still be covered by the program
    guard: a control update racing in after the recompile routes traffic
    to the generic executable (which reads the LIVE tables)."""
    rt = _mk_runtime()
    try:
        rt.control_update("classes", {"scale": _scales(8, 1)})
        rt.recompile(block=True)                 # plan A (compiled)
        rt.control_update("classes", {"scale": _scales(8, 2)})
        rt.recompile(block=True)                 # plan B (compiled)
        c0 = rt.engine.compile_count
        rt.control_update("classes", {"scale": _scales(8, 1)})
        rt.recompile(block=True)                 # plan A again: cache hit
        assert rt.engine.compile_count == c0
        assert rt.stats.cache_hits > 0
        # racing update AFTER the swap — no recompile before the step
        rt.control_update("classes", {"scale": _scales(8, 7)})
        d0 = rt.stats.deopt_steps
        out = rt.step(rt._batch)
        assert rt.stats.deopt_steps == d0 + 1    # guard tripped
        np.testing.assert_allclose(np.asarray(out),
                                   _expected(rt, rt._batch), rtol=1e-6)
    finally:
        rt.close()


def test_instrumented_twins_compiled_distinct_and_concurrently():
    """With instrumented sites the specialized executable and its twin
    are distinct cache entries, compiled in one recompile cycle."""
    rt = _mk_runtime(n_valid=40, instrument=True)
    try:
        assert rt.engine.instrumented_sites()
        assert rt.generic_instr_exec is not rt.generic_exec
        for i in range(4):
            rt.step(rt._batch)
        c0 = rt.engine.compile_count
        rt.control_update("classes", {"scale": _scales(40, 1)})
        rt.recompile(block=True)
        assert rt.plan.label.startswith("specialized")
        assert rt.instr_exec is not rt.exec
        assert rt.engine.compile_count == c0 + 2       # both twins
        # instrumented sampling keeps working after the swap
        s0 = rt.stats.instr_steps
        for i in range(4):
            rt.step(rt._batch)
        assert rt.stats.instr_steps > s0
    finally:
        rt.close()


def test_dispatch_reads_one_consistent_tuple():
    rt = _mk_runtime()
    try:
        plan, exe, instr_exe, generic_exe = rt._active
        assert rt.plan is plan
        assert rt.exec is exe
        assert rt.instr_exec is instr_exe
        assert rt.generic_exec is generic_exe
        rt.recompile(block=True)
        assert rt.plan is rt._active[0]          # swap replaced the tuple
    finally:
        rt.close()


def test_run_generic_oracle_shares_the_cache():
    rt = _mk_runtime()
    try:
        n0 = len(rt.exec_cache)
        s0 = rt.stats.cache_hits + rt.stats.cache_misses
        out1 = rt.run_generic(rt._batch)
        assert len(rt.exec_cache) == n0 + 1      # donate=False twin added
        h0 = rt.exec_cache.stats.hits
        out2 = rt.run_generic(rt._batch)         # second call: cache hit
        assert rt.exec_cache.stats.hits > h0
        # oracle traffic stays OUT of the serving-cycle counters
        assert rt.stats.cache_hits + rt.stats.cache_misses == s0
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
        # the oracle key differs from the serving key only in donate
        k_serve = rt._exec_key(rt.generic_plan, rt._batch, True,
                               rt._isites())
        k_oracle = rt._exec_key(rt.generic_plan, rt._batch, False,
                                rt._isites())
        assert k_serve != k_oracle
        assert k_serve[:-1] == k_oracle[:-1]
    finally:
        rt.close()


def test_shared_cache_across_runtimes():
    """The multi-dataplane seam: two runtimes, one ExecutableCache —
    distinct namespaces keep their executables apart by default."""
    cache = ExecutableCache(capacity=32)
    rt1 = _mk_runtime(cache=cache)
    rt2 = _mk_runtime(cache=cache)
    try:
        assert rt1.exec_cache is cache and rt2.exec_cache is cache
        assert rt1._cache_ns != rt2._cache_ns
        n_generic = len(cache)                   # both generics cached
        assert n_generic >= 2
        rt1.recompile(block=True)
        rt2.recompile(block=True)
        out1, out2 = rt1.step(rt1._batch), rt2.step(rt2._batch)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)
        assert len(cache) >= n_generic + 2       # one specialized each
    finally:
        rt1.close()
        rt2.close()


def test_version_keyed_baseline_recompiles_every_cycle():
    """EngineConfig(signature_cache=False) reproduces the pre-cache
    behavior the benchmark measures against: every version bump forces
    a full recompile of behaviorally identical code."""
    rt = _mk_runtime(signature_cache=False)
    try:
        rt.recompile(block=True)
        c0 = rt.engine.compile_count
        rt.tables.bump_version("churn")
        info = rt.recompile(block=True)
        assert info["revalidated"] is False
        assert rt.engine.compile_count > c0
        assert rt.stats.revalidations == 0
    finally:
        rt.close()


def test_instr_structure_change_forces_swap_not_revalidation():
    """A control update that flips a site in or out of instrumentation
    (n_valid crossing max_inline) changes the PlaneState treedef while
    leaving the plan signature unchanged — the cycle must recompile
    against the new structure, never revalidate the old executable."""
    def rw_step(params, ctx, batch):
        row = ctx.lookup("sess", batch["cls"], fields=("val",))
        ctx.update("sess", batch["cls"],
                   {"val": row["val"] + 1.0})
        return row["val"]

    tables = TableSet([Table("sess", {"val": np.zeros(64, np.float32)},
                             n_valid=8, instrument=True)])
    batch = {"cls": jnp.arange(8, dtype=jnp.int32)}
    rt = MorpheusRuntime(rw_step, tables, None, batch,
                         cfg=EngineConfig(sketch=SketchConfig(
                             sample_every=2, max_hot=4)))
    try:
        assert rt.engine.instrumented_sites() == []     # 8 <= max_inline
        rt.recompile(block=True)
        sig0 = rt.plan.signature
        # grow past the inline threshold: the site becomes instrumented,
        # the state pytree gains a sketch — but the plan stays the same
        rt.control_update("sess", {"val": np.zeros(64, np.float32)},
                          n_valid=40)
        assert rt.engine.instrumented_sites() == ["sess#0"]
        info = rt.recompile(block=True)
        assert rt.plan.signature == sig0                # same plan...
        assert info["revalidated"] is False             # ...new structure
        assert "sess#0" in rt.state.instr
        for i in range(4):                              # incl. sampled
            out = rt.step(batch)                        # instrumented steps
        assert np.isfinite(np.asarray(out)).all()
        # deopt target was refreshed for the new structure too
        rt.tables.bump_version("late-update")
        d0 = rt.stats.deopt_steps
        rt.step(batch)
        assert rt.stats.deopt_steps == d0 + 1
    finally:
        rt.close()


def test_batch_key_distinguishes_shapes_and_dtypes():
    b1 = {"x": jnp.ones((8, 4))}
    b2 = {"x": jnp.ones((4, 4))}
    b3 = {"x": jnp.ones((8, 4), jnp.bfloat16)}
    assert batch_key(b1) != batch_key(b2)
    assert batch_key(b1) != batch_key(b3)
    assert batch_key(b1) == batch_key({"x": jnp.zeros((8, 4))})
