"""Training-plane chaos cells (see repro.testing.chaos, training
section).  Each cell drives a real MoE smoke model through the
TrainSupervisor under one injected fault class and asserts the
robustness obligations:

  crash_resume  bit-exact replay of the never-crashed trajectory after
                a SIGKILL-equivalent crash + --resume, with zero
                training-thread compiles at resume;
  step_fault    deopt + same-batch retry, optimizer step counter
                advances exactly once per batch, terminal
                re-specialized;
  device_loss   snapshot -> mesh shrink -> verified elastic reshard ->
                degraded generic -> background re-specialization;
  compile       bounded-backoff absorption of short bursts, signature
                quarantine past max_retries, training survives both.

The harness itself raises ConformanceError on any violated obligation;
the assertions here pin the report shape."""
import pytest

from repro.testing import TRAIN_SCENARIOS, run_train_chaos


@pytest.mark.parametrize("scenario", TRAIN_SCENARIOS,
                         ids=[f"train-chaos-{s}" for s in TRAIN_SCENARIOS])
def test_train_chaos_cell(scenario):
    report = run_train_chaos(scenario, seed=0)
    assert report["scenario"] == scenario
    if scenario == "crash_resume":
        assert report["bit_exact"] is True
        # the one sync compile is the constructor's resident generic
        assert report["resume_stats"]["sync_compiles"] == 1
        assert report["resume_stats"]["bg_compiles"] >= 1
    elif scenario == "step_fault":
        assert report["stats"]["step_faults"] == 1
        assert report["stats"]["respecialize_recoveries"] >= 1
    elif scenario == "device_loss":
        assert report["stats"]["device_losses"] == 1
        assert report["stats"]["reshard_verified"] == 1
        assert report["stats"]["mesh_epoch"] == 1
    elif scenario == "compile":
        assert report["absorbed_stats"]["quarantines"] == 0
        assert report["quarantine_stats"]["quarantines"] == 1
