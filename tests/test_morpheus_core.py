"""Morpheus core: analysis, instrumentation, passes, guards, runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, MorpheusRuntime, SketchConfig, Table, \
    TableSet
from repro.core import instrument
from repro.core.passes.const_prop import constant_fields, propose_const_row
from repro.core.passes.dstruct import lookup_cost, propose_dstruct
from repro.core.passes.table_jit import propose_eliminate, propose_inline
from repro.serving import ServeConfig, build_params, build_tables, \
    make_synthetic_batch, make_serve_step

KEY = jax.random.PRNGKey(0)
SK = SketchConfig(sample_every=2, max_hot=4, hot_coverage=0.5)


# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------

def test_sketch_heavy_hitters():
    state = instrument.init_site_state(SK)
    rng = np.random.default_rng(0)
    # 90% of lookups hit keys {3, 7}; the rest are uniform over 1000
    for _ in range(20):
        hot = rng.choice([3, 7], size=180)
        cold = rng.integers(0, 1000, size=20)
        keys = jnp.asarray(np.concatenate([hot, cold]), jnp.int32)
        state = instrument.record(state, keys, SK)
    hot, cov, total = instrument.hot_keys(state, SK)
    assert total == 4000
    assert set(hot[:2].tolist()) == {3, 7}
    assert cov > 0.8


def test_sketch_estimate_overcounts_only():
    state = instrument.init_site_state(SK)
    keys = jnp.asarray(np.repeat(np.arange(50), 10), jnp.int32)
    state = instrument.record(state, keys, SK)
    est = np.asarray(instrument.estimate(state, jnp.arange(50)))
    assert (est >= 10).all()          # count-min never undercounts


def test_sketch_merge():
    a = instrument.init_site_state(SK)
    b = instrument.init_site_state(SK)
    a = instrument.record(a, jnp.full((64,), 5, jnp.int32), SK)
    b = instrument.record(b, jnp.full((64,), 5, jnp.int32), SK)
    m = instrument.merge([a, b])
    assert int(instrument.estimate(m, jnp.asarray([5]))[0]) >= 128


def test_adaptive_controller_backs_off():
    ctl = instrument.AdaptiveController(SK)
    e0 = ctl.sample_every
    for _ in range(4):
        ctl.observe("s", np.array([1, 2, 3]))
    assert ctl.sample_every > e0          # stable hot set -> sample less
    stable = ctl.sample_every
    ctl.observe("s", np.array([9, 9, 9]))
    assert ctl.sample_every < stable        # churn -> sample more


# ---------------------------------------------------------------------------
# passes (unit)
# ---------------------------------------------------------------------------

def _table(n_valid, cap=32, const=False):
    rng = np.random.default_rng(1)
    vals = (np.ones((cap, 8), np.float32) if const
            else rng.standard_normal((cap, 8)).astype(np.float32))
    return Table("t", {"v": vals, "f": np.zeros(cap, np.int32)},
                 n_valid=n_valid, default={"v": 0.0})


def test_pass_eliminate_empty():
    assert propose_eliminate(_table(0)).impl == "eliminated"
    assert propose_eliminate(_table(3)) is None


def test_pass_inline_small_ro():
    t = _table(4)
    spec = propose_inline(t, "ro")
    assert spec.impl == "inline_const"
    assert propose_inline(t, "rw") is None
    assert propose_inline(_table(30), "ro") is None   # too big


def test_pass_const_prop():
    t = _table(8, const=True)
    assert set(constant_fields(t)) == {"v", "f"}
    assert propose_const_row(t, "ro").impl == "const_row"
    assert propose_const_row(_table(8), "ro") is None


def test_dstruct_cost_model_prefers_onehot_small():
    small, big = _table(8), _table(32, cap=4096)
    big.fields["v"] = np.zeros((4096, 8), np.float32)
    big.n_valid = 4096
    assert lookup_cost(small, "onehot", 1024) < lookup_cost(
        small, "gather", 1024)
    spec = propose_dstruct(big, "ro")
    # large tables may keep the gather
    assert spec is None or spec.impl == "onehot"


# ---------------------------------------------------------------------------
# end-to-end runtime
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def runtime():
    cfg = ServeConfig()
    params = build_params(cfg, KEY)
    tables = build_tables(cfg, KEY)
    step = make_serve_step(cfg)
    ecfg = EngineConfig(sketch=SK,
                        features={"vision_enabled": False,
                                  "track_sessions": True},
                        moe_router_table="router")
    rt = MorpheusRuntime(step, tables, params,
                         make_synthetic_batch(cfg, KEY), cfg=ecfg)
    rt._serve_cfg = cfg
    return rt


def test_analysis_classifies_tables(runtime):
    assert runtime.analysis["mutability"]["sessions"] == "rw"
    assert runtime.analysis["mutability"]["req_class"] == "ro"
    assert runtime.analysis["n_sites"] >= 5


def test_specialization_preserves_semantics(runtime):
    cfg = runtime._serve_cfg
    for i in range(6):
        runtime.step(make_synthetic_batch(cfg, jax.random.PRNGKey(i)))
    runtime.recompile(block=True)
    assert runtime.plan.label.startswith("specialized")
    batch = make_synthetic_batch(cfg, jax.random.PRNGKey(77))
    out_s = runtime.step(batch)
    out_g = runtime.run_generic(batch)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_g),
                               rtol=1e-5, atol=1e-5)


def test_empty_adapter_table_eliminated(runtime):
    impls = dict((sid.split("#")[0], s.impl) for sid, s in
                 runtime.plan.sites)
    assert impls.get("adapters") == "eliminated"


def test_guard_elision_ro_sites(runtime):
    for sid, s in runtime.plan.sites:
        if not sid.startswith("sessions"):
            assert not s.guarded, f"RO site {sid} should elide its guard"


def test_program_guard_deopt_and_recovery(runtime):
    cfg = runtime._serve_cfg
    batch = make_synthetic_batch(cfg, jax.random.PRNGKey(5))
    runtime.recompile(block=True)
    d0 = runtime.stats.deopt_steps
    runtime.control_update(
        "req_class",
        {"temperature": np.full(cfg.n_classes, 2.0, np.float32)})
    out = runtime.step(batch)          # program guard must route generic
    assert runtime.stats.deopt_steps == d0 + 1
    # new temperature must be live immediately (generic path reads tables)
    runtime.recompile(block=True)
    out2 = runtime.step(batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_dead_code_flag_shrinks_program(runtime):
    cfg = runtime._serve_cfg
    eng = runtime.engine
    plan_off, _, _ = eng.build_plan({})
    import dataclasses
    plan_on = dataclasses.replace(
        plan_off, flags={**plan_off.flags, "vision_enabled": True})
    batch = make_synthetic_batch(cfg, KEY)
    args = (runtime.params, runtime.state, batch)
    jx_off = jax.make_jaxpr(eng.make_step_fn(plan_off))(*args)
    jx_on = jax.make_jaxpr(eng.make_step_fn(plan_on))(*args)
    assert len(jx_off.jaxpr.eqns) < len(jx_on.jaxpr.eqns)


def test_rw_update_invalidates_site_guard(runtime):
    cfg = runtime._serve_cfg
    batch = make_synthetic_batch(cfg, KEY)
    runtime.state = runtime.state.replace(
        guards=runtime.engine.init_guards())
    assert int(runtime.state.guards["sessions"][0]) == 0
    runtime.step(batch)                # step writes sessions
    assert int(runtime.state.guards["sessions"][0]) == 1
