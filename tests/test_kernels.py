"""Per-kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.hot_gather import hot_gather_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,Hkv,D,causal,window,cap",
    [
        (1, 64, 64, 4, 4, 32, True, None, 0.0),      # MHA causal
        (2, 100, 100, 4, 2, 32, True, None, 0.0),    # GQA, ragged seq
        (1, 64, 64, 4, 1, 64, True, None, 0.0),      # MQA
        (1, 96, 96, 2, 2, 32, True, 32, 50.0),       # window + softcap
        (1, 64, 64, 4, 4, 32, False, None, 0.0),     # bidirectional
        (2, 1, 128, 4, 2, 32, True, None, 0.0),      # decode-shaped q
    ])
def test_flash_attention_vs_oracle(B, Sq, Sk, H, Hkv, D, causal, window,
                                   cap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 logit_softcap=cap, blk_q=32, blk_k=32,
                                 interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window,
                                logit_softcap=cap, block=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_decode_q1_matches_full_row():
    """Single-query attention equals the last row of full attention."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 33, 4, 32))
    k = jax.random.normal(ks[1], (1, 33, 4, 32))
    v = jax.random.normal(ks[2], (1, 33, 4, 32))
    full = flash_attention_kernel(q, k, v, causal=True, blk_q=16,
                                  blk_k=16, interpret=True)
    one = flash_attention_kernel(q[:, -1:], k, v, causal=False, blk_q=16,
                                 blk_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(one[0, 0]),
                               np.asarray(full[0, -1]), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk,hblk", [
    (1, 32, 4, 8, 16, 8, 4),
    (2, 48, 8, 16, 32, 16, 4),
    (1, 40, 2, 8, 16, 16, 2),       # S not divisible by chunk
    (2, 64, 8, 16, 16, 32, 8),
])
def test_ssd_scan_vs_oracle(B, S, H, P, N, chunk, hblk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(
        jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, S, 1, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, 1, N)) * 0.3).astype(dtype)
    y, fin = ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk, hblk=hblk,
                             interpret=True)
    yr, finr = R.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr),
                               rtol=1e-3, atol=1e-3)


def test_ssd_scan_chunk_invariance():
    """The chunk size is a tiling choice — results must not depend on it."""
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 1, 64, 4, 8, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.3
    y8, f8 = R.ssd_scan_ref(x, dt, A, Bm, Cm, 8)
    y32, f32_ = R.ssd_scan_ref(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(f8), np.asarray(f32_),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_scan():
    """Step-by-step decode must track the chunked scan state."""
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 1, 16, 2, 4, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.3
    y_scan, fin = R.ssd_scan_ref(x, dt, A, Bm, Cm, 8)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, state = R.ssd_decode_ref(x[:, t], dt[:, t], A, Bm[:, t],
                                      Cm[:, t], state)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_scan),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(fin),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# hot_gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,D,Hn,T", [
    (64, 16, 4, 32),
    (512, 64, 8, 100),
    (128, 32, 1, 7),
])
def test_hot_gather_vs_oracle(V, D, Hn, T, dtype):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((V, D)), dtype)
    hot_ids = jnp.asarray(rng.choice(V, Hn, replace=False), jnp.int32)
    hot_rows = jnp.take(table, hot_ids, axis=0)
    idx = jnp.asarray(rng.integers(0, V, T), jnp.int32)
    out = hot_gather_kernel(table, hot_rows, hot_ids, idx, interpret=True)
    ref = R.hot_gather_ref(table, hot_rows, hot_ids, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0, atol=0)
    # exactness property: identical to a plain gather
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.take(table, idx, axis=0)))


def test_hot_gather_all_hot_and_all_cold():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    hot_ids = jnp.asarray([1, 2, 3], jnp.int32)
    hot_rows = table[hot_ids]
    all_hot = jnp.asarray([1, 2, 3, 1, 2], jnp.int32)
    all_cold = jnp.asarray([9, 10, 11], jnp.int32)
    for idx in (all_hot, all_cold):
        out = hot_gather_kernel(table, hot_rows, hot_ids, idx,
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(table[idx]))
