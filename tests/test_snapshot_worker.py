"""Off-thread t1 snapshotting: versioned copy-on-write handoff."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import EngineConfig, MorpheusRuntime, SketchConfig, Table, \
    TableSet, TableSnapshotWorker
from repro.serving import ServeConfig, build_params, build_tables, \
    make_synthetic_batch, make_serve_step


def _tables(n=4):
    return TableSet([Table("t", {"a": np.zeros(n, np.int64),
                                 "b": np.zeros(n, np.int64)},
                           n_valid=n)])


def test_snapshot_runs_on_worker_thread():
    ts = _tables()
    w = TableSnapshotWorker(ts)
    try:
        snap = w.get(0)
        assert snap.version == 0
        assert snap.thread_ident != threading.get_ident()
        assert snap.thread_ident == w._thread.ident
        assert snap.thread_name == "morpheus-snapshot"
    finally:
        w.stop()


def test_get_waits_for_requested_version():
    ts = _tables()
    w = TableSnapshotWorker(ts)
    try:
        assert w.get(0).version == 0
        v = ts.control_update("t", {"a": np.arange(4)})
        snap = w.get(v)
        assert snap.version == v
        np.testing.assert_array_equal(snap.tables["t"].fields["a"],
                                      np.arange(4))
        with pytest.raises(TimeoutError):
            w.get(v + 100, timeout=0.2)     # future version never arrives
    finally:
        w.stop()


def test_cow_snapshot_immune_to_later_updates():
    """The handed-off snapshot is frozen at its version: control-plane
    writes after the handoff must not leak into it (copy-on-write)."""
    ts = _tables()
    ts.control_update("t", {"a": np.full(4, 7), "b": np.full(4, 7)})
    w = TableSnapshotWorker(ts)
    try:
        snap = w.get(ts.version)
        ts.control_update("t", {"a": np.full(4, 9), "b": np.full(4, 9)})
        np.testing.assert_array_equal(snap.tables["t"].fields["a"],
                                      np.full(4, 7))
        fresh = w.get(ts.version)
        np.testing.assert_array_equal(fresh.tables["t"].fields["a"],
                                      np.full(4, 9))
    finally:
        w.stop()


def test_concurrent_updates_observe_consistent_versions():
    """Hammer the TableSet from writer threads while snapshotting: every
    snapshot must be internally consistent (paired fields agree — no torn
    reads) and stamped with the version its contents belong to."""
    ts = _tables()
    w = TableSnapshotWorker(ts)
    stop = threading.Event()
    expected = {0: 0}

    def writer():
        i = 0
        while not stop.is_set():
            i += 1
            v = ts.control_update("t", {"a": np.full(4, i),
                                        "b": np.full(4, i)})
            expected[v] = i
            time.sleep(0)

    th = threading.Thread(target=writer)
    th.start()
    try:
        seen = 0
        for _ in range(200):
            snap = w.get(None, timeout=5.0)
            t = snap.tables["t"]
            a, b = t.fields["a"], t.fields["b"]
            np.testing.assert_array_equal(a, b)       # no torn snapshot
            assert (a == a[0]).all()
            assert expected[snap.version] == int(a[0])  # version matches
            seen += 1
        assert seen == 200
    finally:
        stop.set()
        th.join()
        w.stop()


def test_stopped_worker_raises():
    w = TableSnapshotWorker(_tables())
    w.stop()
    with pytest.raises(RuntimeError):
        w.get(0)


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def runtime():
    cfg = ServeConfig()
    key = jax.random.PRNGKey(0)
    rt = MorpheusRuntime(
        make_serve_step(cfg), build_tables(cfg, key),
        build_params(cfg, key), make_synthetic_batch(cfg, key),
        cfg=EngineConfig(sketch=SketchConfig(sample_every=2, max_hot=4,
                                             hot_coverage=0.5),
                         features={"vision_enabled": False,
                                   "track_sessions": True},
                         moe_router_table="router"))
    yield cfg, rt
    rt.close()


def test_recompile_t1_snapshot_off_caller_thread(runtime):
    """The acceptance criterion: even a blocking recompile never runs the
    t1 table snapshot on the control-plane caller's thread."""
    cfg, rt = runtime
    for i in range(4):
        rt.step(make_synthetic_batch(cfg, jax.random.PRNGKey(i), 8))
    info = rt.recompile(block=True)
    assert info is not None
    snap = rt.last_snapshot
    assert snap is not None
    assert snap.thread_ident != threading.get_ident()
    assert snap.thread_ident == rt.snapshot_worker._thread.ident
    assert rt.stats.snapshot_versions[-1] == snap.version


def test_recompile_uses_snapshot_version_not_live_version(runtime):
    """A control update racing past the snapshot leaves the new plan
    stamped with the snapshot's version, so the program guard deopts it
    instead of serving a plan that claims to match newer tables."""
    cfg, rt = runtime
    snap = rt.snapshot_worker.get(rt.tables.version)
    plan, _, _ = rt.engine.build_plan({}, snapshot=snap.tables,
                                      version=snap.version)
    assert plan.version == snap.version
    with pytest.raises(ValueError):
        # an injected snapshot without its version would get stamped
        # with the live version and dodge the deopt guard
        rt.engine.build_plan({}, snapshot=snap.tables)
    rt.control_update("req_class",
                      {"temperature": np.full(4, 1.5, np.float32)})
    assert rt.tables.version > plan.version   # guard would deopt this plan
    rt.recompile(block=True)
    assert rt.plan.version == rt.tables.version


def test_close_is_final_and_idempotent(runtime):
    """After close(), recompiles raise instead of silently restarting
    the worker thread (a background recompile racing close() must not
    resurrect it).  Runs last in this module: the fixture's teardown
    close() stays a no-op."""
    cfg, rt = runtime
    rt.close()
    with pytest.raises(RuntimeError):
        rt.recompile(block=True)
    rt.close()                                # idempotent
    # the data plane keeps serving
    out = rt.step(make_synthetic_batch(cfg, jax.random.PRNGKey(7), 8))
    assert np.isfinite(np.asarray(out)).all()
