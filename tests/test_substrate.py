"""Substrate tests: data pipeline, checkpointing, fault tolerance,
optimizer, microbatching."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save, save_async
from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.distributed.fault import FailureInjector, SimulatedFailure, \
    StragglerMonitor
from repro.launch.steps import make_train_step
from repro.launch.train import build_state
from repro.models import Model, unzip
from repro.optim import AdamWConfig, adamw_update, init_opt_state, schedule

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=128, seq=16, global_batch=4, seed=7)
    p1 = TokenPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.state_dict()
    more = [p1.next_batch() for _ in range(3)]

    p2 = TokenPipeline(cfg)
    p2.load_state_dict(state)
    resumed = [p2.next_batch() for _ in range(3)]
    for a, b in zip(more, resumed):
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


def test_pipeline_zipf_skew():
    cfg = DataConfig(vocab=512, seq=64, global_batch=16, seed=0)
    toks = np.asarray(TokenPipeline(cfg).next_batch()["tokens"]).ravel()
    # Zipf: token 0 should be much more common than the tail
    assert (toks == 0).sum() > (toks >= 256).sum() / 4


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tiny_state():
    cfg = get_config("llama3-8b").smoke()
    model = Model(cfg)
    state, _ = build_state(model, KEY)
    return model, state


def test_checkpoint_roundtrip_bf16():
    model, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, state, meta={"data": {"step": 3, "seed": 0}})
        assert latest_step(d) == 3
        restored, meta = restore(d, None, state)
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_atomic_rename():
    model, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, state)
        # a stale tmp dir from a crashed writer must not break anything
        os.makedirs(os.path.join(d, ".tmp_2"), exist_ok=True)
        save(d, 2, state)
        assert latest_step(d) == 2
        restore(d, 2, state)


def test_checkpoint_async():
    model, state = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        th = save_async(d, 5, state)
        th.join(timeout=60)
        assert latest_step(d) == 5


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_failure_injector():
    inj = FailureInjector(fail_at_step=4)
    for s in range(4):
        inj.check(s)
    with pytest.raises(SimulatedFailure):
        inj.check(4)


def test_straggler_monitor_fires():
    fired = []
    mon = StragglerMonitor(threshold=2.0, patience=2,
                           on_straggler=lambda s, t: fired.append(s))
    for s in range(10):
        mon.observe(s, 0.1)
    mon.observe(10, 0.5)
    mon.observe(11, 0.5)
    assert fired, "straggler mitigation should have fired"


def test_train_crash_resume_end_to_end(tmp_path):
    """Full loop: crash mid-run, resume from the atomic checkpoint, and
    the resumed data stream continues exactly where it left off."""
    args = ["--arch", "starcoder2-3b", "--smoke", "--steps", "14",
            "--batch", "2", "--seq", "16", "--ckpt-every", "5",
            "--ckpt-dir", str(tmp_path), "--log-every", "50"]
    env = {**os.environ, "PYTHONPATH": "src"}
    r1 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args,
         "--fail-at-step", "8"],
        capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert "SimulatedFailure" in r1.stderr
    assert latest_step(str(tmp_path)) == 5
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args, "--resume"],
        capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r2.returncode == 0, r2.stderr[-800:]
    assert "resumed from step 5" in r2.stdout
    assert "done at step 14" in r2.stdout


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_moves_params_and_keeps_dtypes():
    model, state = _tiny_state()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01,
                         state["params"])
    new_p, new_opt, m = adamw_update(AdamWConfig(lr=1e-2), grads,
                                     state["opt"])
    assert int(new_opt["step"]) == 1
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(new_p)):
        assert a.dtype == b.dtype
    diff = max(float(jnp.abs(a.astype(jnp.float32) -
                             b.astype(jnp.float32)).max())
               for a, b in zip(jax.tree.leaves(state["params"]),
                               jax.tree.leaves(new_p)))
    assert diff > 0


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) <= 0.1 + 1e-6


def test_microbatch_grad_accumulation_matches_full_batch():
    cfg = get_config("starcoder2-3b").smoke()
    model = Model(cfg)
    state, _ = build_state(model, KEY)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab)}
    s1 = jax.tree.map(lambda x: x, state)
    s2 = jax.tree.map(lambda x: x, state)
    step1 = make_train_step(model, AdamWConfig(), microbatches=1)
    step2 = make_train_step(model, AdamWConfig(), microbatches=2)
    n1, m1 = jax.jit(step1)(s1, batch)
    n2, m2 = jax.jit(step2)(s2, batch)
    # microbatching is an exact-averaging transformation up to fp error
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    a = jax.tree.leaves(n1["opt"]["master"])[0]
    b = jax.tree.leaves(n2["opt"]["master"])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.1,
                               atol=1e-3)


def test_train_morpheus_hot_expert_swap(tmp_path):
    """Morpheus on the training backend: the driver re-plans hot experts
    from router statistics and swaps in the branch-injected step; loss
    stays finite and decreasing across the swap (cond-guard exactness)."""
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "phi3.5-moe-42b-a6.6b", "--smoke", "--steps", "24",
         "--batch", "2", "--seq", "16", "--ckpt-every", "0",
         "--respecialize-every", "8", "--hot-coverage", "0.7",
         "--log-every", "100"],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=560)
    assert r.returncode == 0, r.stderr[-800:]
    assert "morpheus: swapped in hot-expert step" in r.stdout
    assert "done at step 24" in r.stdout
